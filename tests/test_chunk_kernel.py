"""Differential suite for the fused Pallas chunk/decode serving kernel.

Pins ``kernels/chunk_attn.py`` (interpret mode — the TPU serving path run on
CPU) to the pure-jnp ``mra2_chunk_attention`` / ``mra2_decode_attention``
formulation across the axes where a data-dependent paged kernel can silently
go wrong (DESIGN.md §11): ring paging × int8 quantization × coarse_only ×
GQA × ragged lengths × chunk-vs-decode × MRA-2/MRA-2-s, plus the exact
softmax oracle at full budget and the engine-level token conformance test
(the jnp engine and the kernel engine must emit identical streams).
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mra import MraConfig
from repro.core.mra_decode import (
    PyramidState,
    full_chunk_attention,
    identity_page_table,
    mra2_chunk_attention,
    mra2_decode_attention,
    paged_position_mask,
    quantize_kv,
)


@dataclasses.dataclass(frozen=True)
class Case:
    """One point of the serving-kernel differential sweep."""

    paged: bool = False        # ring layout (stream longer than the cache)
    quant: bool = False        # int8 pages + per-token scales
    coarse_only: bool = False  # m = 1: own block + pyramid background only
    group: int = 1             # GQA: Hq = group * Hkv
    ragged: bool = False       # per-slot lengths (incl. a zero-length slot)
    variant: str = "full"
    B: int = 2
    Hkv: int = 2
    S: int = 64
    D: int = 8
    b: int = 16
    m: int = 3
    seed: int = 0

    @property
    def id(self) -> str:
        return (
            f"{'ring' if self.paged else 'dense'}-{'int8' if self.quant else 'fp'}"
            f"-{'coarse' if self.coarse_only else f'm{self.m}'}-g{self.group}"
            f"-{'ragged' if self.ragged else 'full'}-{self.variant}"
        )


# every combination of the risky axes (64 cases x {decode, chunk})
SWEEP = [
    Case(paged=p, quant=qz, coarse_only=co, group=g, ragged=rg, variant=v,
         seed=i)
    for i, (p, qz, co, g, rg, v) in enumerate(
        itertools.product([False, True], [False, True], [False, True], [1, 2],
                          [False, True], ["full", "sparse"])
    )
]


def _cfgs(case: Case, mode: str = "auto"):
    kw = dict(block_size=case.b, causal=True, variant=case.variant)
    return (MraConfig(**kw),
            MraConfig(**kw, use_kernel=True, interpret=True,
                      kernel_mode=mode))


def make_case_inputs(case: Case, *, C: int = 1, min_len: int = 0):
    """(q, k, v, lengths, q_pos, page_blocks, k_scale, v_scale) for a case.

    ``min_len`` bounds the ragged lengths from below. The serving contract is
    ``q_pos <= lengths - 1`` (chunk queries are tokens already written to the
    cache); with ``min_len < C`` some q_pos run past the stream — fine for
    kernel↔jnp parity (identical math both sides) but out of contract for
    exact-oracle comparisons, which must pass ``min_len=C``.
    """
    r = np.random.default_rng(case.seed)
    B, Hkv, S, D, b = case.B, case.Hkv, case.S, case.D, case.b
    nb = S // b
    Hq = Hkv * case.group
    k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    q = jnp.asarray(r.standard_normal((B, Hq, C, D)), jnp.float32)
    page_blocks = None
    if case.paged:
        # a 1.5x-capacity stream through the ring: logical blocks
        # nb/2 .. 3nb/2-1, block y on physical page y % nb
        lengths = np.full((B,), S + S // 2)
        page_blocks = jnp.roll(
            jnp.broadcast_to((jnp.arange(nb, dtype=jnp.int32) + nb // 2)[None],
                             (B, nb)), nb // 2, axis=1)
    elif case.ragged:
        lengths = np.array([min_len] + list(r.integers(max(min_len, 1), S + 1,
                                                       B - 1)))
    else:
        lengths = np.full((B,), S)
    lengths = jnp.asarray(lengths, jnp.int32)
    q_pos = jnp.maximum(lengths[:, None] - C, 0) + jnp.arange(C)
    k_scale = v_scale = None
    if case.quant:
        k, k_scale = quantize_kv(k)
        v, v_scale = quantize_kv(v)
    return q, k, v, lengths, q_pos, page_blocks, k_scale, v_scale


@pytest.mark.parametrize("case", SWEEP, ids=lambda c: c.id)
@pytest.mark.parametrize("mode", ["decode", "chunk"])
def test_kernel_matches_jnp(case: Case, mode: str):
    """Fused kernel == jnp path across the full risky-axis sweep."""
    C = 1 if mode == "decode" else 8
    q, k, v, lengths, q_pos, pb, ks, vs = make_case_inputs(case, C=C)
    m = 1 if case.coarse_only else case.m
    cfg, cfgk = _cfgs(case)
    kw = dict(decode_blocks=m, page_blocks=pb, k_scale=ks, v_scale=vs)
    if mode == "decode":
        ref = mra2_decode_attention(q, k, v, lengths, cfg, **kw)
        out = mra2_decode_attention(q, k, v, lengths, cfgk, **kw)
    else:
        ref = mra2_chunk_attention(q, k, v, lengths, q_pos, cfg, **kw)
        out = mra2_chunk_attention(q, k, v, lengths, q_pos, cfgk, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)


# a small cross-section of the sweep re-run with the mode *forced* (the main
# sweep covers both instantiations through "auto": decode -> latency, chunk
# -> throughput; this pins the off-diagonal pairings — latency tiling on
# chunks, throughput tiling on single queries — without doubling wall time)
FORCED = [Case(), Case(paged=True, quant=True, seed=21),
          Case(ragged=True, group=2, seed=33),
          Case(quant=True, variant="sparse", coarse_only=True, seed=40)]


@pytest.mark.parametrize("case", FORCED, ids=lambda c: c.id)
@pytest.mark.parametrize("mode", ["latency", "throughput"])
@pytest.mark.parametrize("C", [1, 5])
def test_kernel_forced_modes_match_jnp(case: Case, mode: str, C: int):
    """Each forced tile shape == jnp at both a decode (C=1) and a *ragged*
    chunk width (C=5: not a multiple of the throughput C_tile, so the padded
    tail rows must select nothing and slice away cleanly)."""
    q, k, v, lengths, q_pos, pb, ks, vs = make_case_inputs(case, C=C)
    m = 1 if case.coarse_only else case.m
    cfg, cfgk = _cfgs(case, mode)
    kw = dict(decode_blocks=m, page_blocks=pb, k_scale=ks, v_scale=vs)
    ref = mra2_chunk_attention(q, k, v, lengths, q_pos, cfg, **kw)
    out = mra2_chunk_attention(q, k, v, lengths, q_pos, cfgk, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["latency", "throughput"])
def test_kernel_oversubscribed_budget(mode: str):
    """m > live blocks: the padded selection slots (top_k returns m indices
    even when fewer pages are valid) must contribute nothing, in-kernel and
    in jnp alike — budget == nb with mostly-dead rings."""
    case = Case(m=4, seed=5)  # m == nb: every slot oversubscribed below
    q, k, v, lengths, q_pos, pb, ks, vs = make_case_inputs(case, C=5)
    lengths = jnp.asarray([1, 17], jnp.int32)  # 1 and 2 live blocks of 4
    q_pos = jnp.maximum(lengths[:, None] - 5, 0) + jnp.arange(5)
    cfg, cfgk = _cfgs(case, mode)
    kw = dict(decode_blocks=case.m)
    ref = mra2_chunk_attention(q, k, v, lengths, q_pos, cfg, **kw)
    out = mra2_chunk_attention(q, k, v, lengths, q_pos, cfgk, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    # and against the exact oracle where the contract holds (slot 1: full
    # budget over its live prefix, C <= len): approximation == exact softmax
    exact = full_chunk_attention(q, k, v, lengths, q_pos)
    np.testing.assert_allclose(np.asarray(out)[1], np.asarray(exact)[1],
                               atol=1e-4)


@pytest.mark.parametrize("route", ["jnp", "latency", "throughput"])
def test_fresh_slot_zero_live_query_block_is_zero(route: str):
    """Regression (PR 7): a query whose block holds zero live tokens — a
    fresh slot attending before any cache write lands — must produce exact
    zeros. The old selection sentinel (``top_vals > NEG_INF * 0.5``) let the
    FORCE_BONUS of the dead own block pass the threshold, so the row
    attended stale cache garbage through the position mask."""
    case = Case(seed=13)
    q, k, v, _, _, _, _, _ = make_case_inputs(case, C=2)
    lengths = jnp.asarray([0, 37], jnp.int32)
    q_pos = jnp.asarray([[0, 1], [35, 36]], jnp.int32)  # slot 0: dead block
    cfg, cfgk = _cfgs(case, route if route != "jnp" else "auto")
    use = cfg if route == "jnp" else cfgk
    out = np.asarray(mra2_chunk_attention(q, k, v, lengths, q_pos, use,
                                          decode_blocks=case.m))
    assert np.abs(out[0]).max() == 0.0  # exact zeros, not garbage
    assert np.abs(out[1]).max() > 0.0  # the live slot still attends
    if route != "jnp":  # and the routes agree on the live slot
        ref = mra2_chunk_attention(q, k, v, lengths, q_pos, cfg,
                                   decode_blocks=case.m)
        np.testing.assert_allclose(out, np.asarray(ref), atol=2e-5, rtol=1e-5)


def test_bad_shapes_raise_value_errors():
    """Shape misuse fails with named shapes, not bare asserts (which vanish
    under ``python -O``) — S % b, GQA grouping, q_pos, kernel_mode."""
    case = Case()
    q, k, v, lengths, q_pos, _, _, _ = make_case_inputs(case, C=1)
    cfg, cfgk = _cfgs(case)
    with pytest.raises(ValueError, match="multiple of block_size"):
        mra2_chunk_attention(q, k[:, :, :60], v[:, :, :60], lengths, q_pos,
                             cfg, decode_blocks=2)
    with pytest.raises(ValueError, match="q_pos shape"):
        mra2_chunk_attention(q, k, v, lengths, jnp.zeros((2, 3), jnp.int32),
                             cfg, decode_blocks=2)
    q3 = jnp.concatenate([q, q[:, :1]], axis=1)  # 3 query heads, 2 KV heads
    with pytest.raises(ValueError, match="KV heads"):
        mra2_chunk_attention(q3, k, v, lengths, q_pos, cfg, decode_blocks=2)
    with pytest.raises(ValueError, match="kernel_mode"):
        mra2_chunk_attention(q, k, v, lengths, q_pos,
                             dataclasses.replace(cfgk, kernel_mode="warp"),
                             decode_blocks=2)


def test_kernel_full_budget_equals_exact_oracle():
    """Budget >= all live pages: the kernel == exact softmax attention —
    an implementation-independent anchor (same as the jnp-path pin)."""
    case = Case(ragged=True, group=2, seed=7)
    q, k, v, lengths, q_pos, pb, ks, vs = make_case_inputs(case, C=8, min_len=8)
    _, cfgk = _cfgs(case)
    out = mra2_chunk_attention(q, k, v, lengths, q_pos, cfgk,
                               decode_blocks=case.S // case.b)
    exact = full_chunk_attention(q, k, v, lengths, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact), atol=1e-4)


def test_kernel_decode_equals_chunk_c1():
    """Kernel route: decode is the C == 1 chunk, same as the jnp contract."""
    case = Case(ragged=True, group=2, seed=3)
    q, k, v, lengths, q_pos, pb, ks, vs = make_case_inputs(case, C=1)
    _, cfgk = _cfgs(case)
    dec = mra2_decode_attention(q, k, v, lengths, cfgk, decode_blocks=2)
    chk = mra2_chunk_attention(q, k, v, lengths, (lengths - 1)[:, None], cfgk,
                               decode_blocks=2)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(dec), atol=1e-6)


def test_kernel_with_incremental_pyramid():
    """The engine's real dataflow: the pyramid block sums ride in instead of
    being recomputed from the cache; kernel == jnp on that path too."""
    case = Case(seed=11)
    q, k, v, lengths, q_pos, _, _, _ = make_case_inputs(case, C=1)
    B, Hkv, S, D, b = case.B, case.Hkv, case.S, case.D, case.b
    nb = S // b
    mask = paged_position_mask(lengths, identity_page_table(B, nb), S,
                               b).astype(jnp.float32)
    pyr = PyramidState(
        jnp.sum((k * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D), axis=3),
        jnp.sum((v * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D), axis=3))
    cfg, cfgk = _cfgs(case)
    ref = mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=2,
                                pyramid=pyr)
    out = mra2_decode_attention(q, k, v, lengths, cfgk, decode_blocks=2,
                                pyramid=pyr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_pyramid_append_past_capacity_is_dropped():
    """Regression (PR 5): ``PyramidState.append`` at ``pos >= nb * block``
    used to scatter at an out-of-range block index, which JAX clamps to
    ``nb - 1`` — silently corrupting the last block's sums. Past-capacity
    appends must be no-ops per slot (ring streams that outlive the capacity
    go through ``ring_pyramid_update`` instead)."""
    r = np.random.default_rng(0)
    B, Hkv, D, nb, block = 2, 2, 4, 4, 8
    kn = r.standard_normal((B, Hkv, D)).astype(np.float32)
    vn = r.standard_normal((B, Hkv, D)).astype(np.float32)
    pyr = PyramidState.init(B, Hkv, nb, D)
    # slot 0 in capacity (lands in block 1), slot 1 exactly at capacity
    pyr = pyr.append(jnp.asarray(kn), jnp.asarray(vn),
                     jnp.asarray([block + 3, nb * block]), block)
    np.testing.assert_allclose(np.asarray(pyr.k_sum)[0, :, 1], kn[0], atol=0)
    assert np.abs(np.asarray(pyr.k_sum)[1]).max() == 0.0  # dropped, not clamped
    assert np.abs(np.asarray(pyr.v_sum)[1]).max() == 0.0
    # way past capacity: still a no-op, nothing NaNs or wraps
    pyr2 = pyr.append(jnp.asarray(kn), jnp.asarray(vn),
                      jnp.asarray([10 * nb * block, nb * block + 1]), block)
    np.testing.assert_array_equal(np.asarray(pyr2.k_sum), np.asarray(pyr.k_sum))
    np.testing.assert_array_equal(np.asarray(pyr2.v_sum), np.asarray(pyr.v_sum))


def test_kernel_is_forward_only():
    """The serving kernel must refuse differentiation loudly (training goes
    through the §3 block-sparse kernels, not this op)."""
    case = Case()
    q, k, v, lengths, q_pos, _, _, _ = make_case_inputs(case, C=1)
    _, cfgk = _cfgs(case)

    def loss(q):
        return jnp.sum(mra2_decode_attention(q, k, v, lengths, cfgk,
                                             decode_blocks=2))

    with pytest.raises(NotImplementedError, match="forward-only"):
        jax.grad(loss)(q)


# --------------------------------------------------------------------------- #
# Engine-level conformance: the kernel serves the same tokens (test_engine.py
# pins the jnp engine to the oracle; this pins the kernel engine to the jnp
# engine, closing the chain end-to-end through prefill_chunk / decode_step).
# --------------------------------------------------------------------------- #
def _engine_requests():
    from repro.serve import Request, SamplingParams

    return [
        Request(prompt=np.arange(1, 20), max_new_tokens=6,
                sampling=SamplingParams(temperature=0.9, seed=7)),
        Request(prompt=np.array([5, 11, 2]), max_new_tokens=2,
                sampling=SamplingParams(temperature=1.0, top_k=5, seed=3)),
        Request(prompt=np.arange(2, 12), max_new_tokens=4),  # greedy
    ]


def test_engine_kernel_path_matches_jnp_engine():
    """Ragged continuous batching through the fused kernel emits identical
    token streams (chunked prefill + decode waves both route through it) —
    under the per-dispatch "auto" mode pick AND with either tile shape
    forced via EngineConfig.kernel_mode (DESIGN.md §11 dual-mode contract)."""
    from repro.configs import get_smoke_config
    from repro.models import get_model, init_params
    from repro.serve import Engine, EngineConfig

    cfg = get_smoke_config("qwen3-1.7b")
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
    ecfg = EngineConfig(slots=3, max_len=64, chunk=8)
    ref = Engine(cfg, params, ecfg).run(_engine_requests())
    by = {len(r.prompt): r.out for r in ref}
    kcfg = cfg.replace(attn_use_kernel=True, attn_interpret=True)
    for mode in ("auto", "latency", "throughput"):
        got = Engine(kcfg, params, ecfg.replace(kernel_mode=mode)).run(
            _engine_requests())
        for r in got:
            np.testing.assert_array_equal(r.out, by[len(r.prompt)],
                                          err_msg=f"kernel_mode={mode}")


def test_engine_rejects_unknown_kernel_mode():
    from repro.configs import get_smoke_config
    from repro.models import get_model, init_params
    from repro.serve import Engine, EngineConfig

    cfg = get_smoke_config("qwen3-1.7b")
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kernel_mode"):
        Engine(cfg, params, EngineConfig(slots=1, kernel_mode="fast"))


def test_engine_kernel_path_speculative_matches_jnp_engine():
    """Speculative serving through the kernel: the coarse-only draft steps,
    the chunked verify dispatch, and ring eviction all hit the fused path
    and still emit the jnp engine's exact tokens (DESIGN.md §10 + §11) —
    in the "auto" per-dispatch pick and with either tile shape forced."""
    from repro.configs import get_smoke_config
    from repro.models import get_model, init_params
    from repro.serve import Engine, EngineConfig, Request

    cfg = get_smoke_config("qwen3-1.7b")
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))

    def reqs():
        return [Request(prompt=np.arange(1, 9), max_new_tokens=20),  # evicts
                Request(prompt=np.array([5, 11, 2]), max_new_tokens=6)]

    ecfg = EngineConfig(slots=2, max_len=32, chunk=8, spec_k=3)
    ref = Engine(cfg, params, ecfg).run(reqs())
    by = {len(r.prompt): r.out for r in ref}
    kcfg = cfg.replace(attn_use_kernel=True, attn_interpret=True)
    for mode in ("auto", "latency", "throughput"):
        eng = Engine(kcfg, params, ecfg.replace(kernel_mode=mode))
        got = eng.run(reqs())
        for r in got:
            np.testing.assert_array_equal(r.out, by[len(r.prompt)],
                                          err_msg=f"kernel_mode={mode}")
        assert eng.stats["spec_rounds"] > 0
