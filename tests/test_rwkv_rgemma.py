"""RWKV6 and RecurrentGemma family-specific correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.models.recurrentgemma import _decay, _rglru_scan
from repro.models.rwkv6 import _decay_clamp, wkv_chunked, wkv_scan


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_wkv_chunked_matches_scan(rng, chunk):
    B, H, T, dh = 2, 3, 64, 8
    r = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    lw = jnp.maximum(
        -jnp.exp(jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)),
        -_decay_clamp(chunk),
    )
    u = jnp.asarray(rng.standard_normal((H, dh)), jnp.float32)
    y_c = wkv_chunked(r, k, v, lw, u, chunk)
    y_s = wkv_scan(r, k, v, lw, u)
    err = float(jnp.abs(y_c - y_s).max() / (jnp.abs(y_s).max() + 1e-9))
    assert err < 1e-4


def test_rwkv_prefill_state_matches_decode_continuation(rng):
    """decode after prefill == decode after stepwise feeding."""
    cfg = get_smoke_config("rwkv6-7b")
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, cfg.rwkv_chunk * 2
    toks = np.random.default_rng(1).integers(1, cfg.vocab, (B, S)).astype(np.int32)
    cache_p = init_params(model.cache_specs(cfg, B, S), jax.random.PRNGKey(1))
    logits_p, cache_p = model.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, cache_p)
    cache_d = init_params(model.cache_specs(cfg, B, S), jax.random.PRNGKey(1))
    for t in range(S):
        logits_d, cache_d = model.decode_step(params, cfg, cache_d, jnp.asarray(toks[:, t]))
    np.testing.assert_allclose(
        np.asarray(cache_p["state"], np.float32), np.asarray(cache_d["state"], np.float32),
        atol=1e-3, rtol=1e-2,
    )
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        atol=0.05, rtol=0.05,
    )


def test_rglru_associative_scan_matches_loop(rng):
    B, T, W = 2, 32, 8
    a = jnp.asarray(rng.random((B, T, W)) * 0.9 + 0.05, jnp.float32)
    bx = jnp.asarray(rng.standard_normal((B, T, W)), jnp.float32)
    _, h = _rglru_scan(a, bx)
    href = np.zeros((B, W), np.float32)
    out = np.zeros((B, T, W), np.float32)
    for t in range(T):
        href = np.asarray(a[:, t]) * href + np.asarray(bx[:, t])
        out[:, t] = href
    np.testing.assert_allclose(np.asarray(h), out, atol=1e-4, rtol=1e-4)


def test_rglru_decay_stable_near_one():
    lam = jnp.array([-10.0, 0.0, 10.0])
    gate = jnp.ones(3)
    a, mult = _decay(lam, gate)
    assert bool(jnp.all((a > 0) & (a < 1)))
    assert bool(jnp.isfinite(mult).all())
    np.testing.assert_allclose(np.asarray(a**2 + mult**2), 1.0, atol=1e-5)


def test_rgemma_ring_buffer_decode_matches_prefill(rng):
    cfg = get_smoke_config("recurrentgemma-9b")
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = np.random.default_rng(2).integers(1, cfg.vocab, (B, S)).astype(np.int32)
    cache_p = init_params(model.cache_specs(cfg, B, 64), jax.random.PRNGKey(1))
    logits_p, _ = model.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, cache_p)
    cache_d = init_params(model.cache_specs(cfg, B, 64), jax.random.PRNGKey(1))
    for t in range(S):
        logits_d, cache_d = model.decode_step(params, cfg, cache_d, jnp.asarray(toks[:, t]))
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        atol=0.05, rtol=0.05,
    )
