"""Property-based tests (hypothesis) for MRA invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from repro.core.mra import MraConfig, block_mean, full_attention, mra2_attention

hypothesis.settings.register_profile(
    "ci", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")

shapes = st.tuples(
    st.sampled_from([1, 2]),          # B
    st.sampled_from([1, 2, 4]),       # Hkv
    st.sampled_from([1, 2]),          # group
    st.sampled_from([32, 48, 64]),    # N
    st.sampled_from([4, 8]),          # D
)


def _data(seed, B, Hkv, G, N, D):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((B, Hkv * G, N, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
    return q, k, v


@given(shapes, st.integers(0, 2**31 - 1), st.booleans())
def test_output_is_convex_combination_of_values(shape, seed, causal):
    """Each output row lies in the convex hull of value rows (per channel)."""
    B, Hkv, G, N, D = shape
    q, k, v = _data(seed, B, Hkv, G, N, D)
    cfg = MraConfig(block_size=8, blocks_per_row=2, causal=causal)
    out = mra2_attention(q, k, v, cfg)
    vmin = jnp.min(v, axis=2, keepdims=True)  # (B,Hkv,1,D)
    vmax = jnp.max(v, axis=2, keepdims=True)
    vmin = jnp.repeat(vmin, Hkv * G // Hkv, axis=1)
    vmax = jnp.repeat(vmax, Hkv * G // Hkv, axis=1)
    eps = 1e-4
    assert bool(jnp.all(out >= vmin - eps)), "below value min"
    assert bool(jnp.all(out <= vmax + eps)), "above value max"


@given(shapes, st.integers(0, 2**31 - 1))
def test_full_budget_exactness_property(shape, seed):
    B, Hkv, G, N, D = shape
    q, k, v = _data(seed, B, Hkv, G, N, D)
    nb = -(-N // 8)
    cfg = MraConfig(block_size=8, blocks_per_row=nb)
    out = mra2_attention(q, k, v, cfg)
    ref = full_attention(q, k, v)
    err = float(jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-9))
    assert err < 1e-4


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
       st.floats(-3, 3), st.floats(0.1, 4))
def test_block_mean_linearity(seed, block, shift, scale):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 32, 4)), jnp.float32)
    y = jnp.asarray(r.standard_normal((2, 32, 4)), jnp.float32)
    lhs = block_mean(scale * x + shift * y, block)
    rhs = scale * block_mean(x, block) + shift * block_mean(y, block)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@given(st.integers(0, 2**31 - 1))
def test_softmax_shift_invariance(seed):
    """Adding a constant to all logits (k -> k + c*1 with q.1 fixed) is absorbed.

    Equivalent check: scaling exp via softmax_scale=0 makes attention uniform.
    """
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    cfg = MraConfig(block_size=8, blocks_per_row=4, softmax_scale=0.0)
    out = mra2_attention(q, k, v, cfg)
    uniform = jnp.broadcast_to(jnp.mean(v, axis=2, keepdims=True), v.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(uniform), atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
def test_head_permutation_equivariance(seed, Hkv):
    """Permuting heads permutes outputs identically."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    perm = np.asarray(np.random.default_rng(seed + 1).permutation(Hkv))
    cfg = MraConfig(block_size=8, blocks_per_row=2)
    out = mra2_attention(q, k, v, cfg)
    out_p = mra2_attention(q[:, perm], k[:, perm], v[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p), atol=1e-5)
