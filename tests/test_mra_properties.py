"""Property-based tests (hypothesis) for MRA invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from repro.core.mra import MraConfig, block_mean, full_attention, mra2_attention

hypothesis.settings.register_profile(
    "ci", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")

shapes = st.tuples(
    st.sampled_from([1, 2]),          # B
    st.sampled_from([1, 2, 4]),       # Hkv
    st.sampled_from([1, 2]),          # group
    st.sampled_from([32, 48, 64]),    # N
    st.sampled_from([4, 8]),          # D
)


def _data(seed, B, Hkv, G, N, D):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((B, Hkv * G, N, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
    return q, k, v


@given(shapes, st.integers(0, 2**31 - 1), st.booleans())
def test_output_is_convex_combination_of_values(shape, seed, causal):
    """Each output row lies in the convex hull of value rows (per channel)."""
    B, Hkv, G, N, D = shape
    q, k, v = _data(seed, B, Hkv, G, N, D)
    cfg = MraConfig(block_size=8, blocks_per_row=2, causal=causal)
    out = mra2_attention(q, k, v, cfg)
    vmin = jnp.min(v, axis=2, keepdims=True)  # (B,Hkv,1,D)
    vmax = jnp.max(v, axis=2, keepdims=True)
    vmin = jnp.repeat(vmin, Hkv * G // Hkv, axis=1)
    vmax = jnp.repeat(vmax, Hkv * G // Hkv, axis=1)
    eps = 1e-4
    assert bool(jnp.all(out >= vmin - eps)), "below value min"
    assert bool(jnp.all(out <= vmax + eps)), "above value max"


@given(shapes, st.integers(0, 2**31 - 1))
def test_full_budget_exactness_property(shape, seed):
    B, Hkv, G, N, D = shape
    q, k, v = _data(seed, B, Hkv, G, N, D)
    nb = -(-N // 8)
    cfg = MraConfig(block_size=8, blocks_per_row=nb)
    out = mra2_attention(q, k, v, cfg)
    ref = full_attention(q, k, v)
    err = float(jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-9))
    assert err < 1e-4


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
       st.floats(-3, 3), st.floats(0.1, 4))
def test_block_mean_linearity(seed, block, shift, scale):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 32, 4)), jnp.float32)
    y = jnp.asarray(r.standard_normal((2, 32, 4)), jnp.float32)
    lhs = block_mean(scale * x + shift * y, block)
    rhs = scale * block_mean(x, block) + shift * block_mean(y, block)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@given(st.integers(0, 2**31 - 1))
def test_softmax_shift_invariance(seed):
    """Adding a constant to all logits (k -> k + c*1 with q.1 fixed) is absorbed.

    Equivalent check: scaling exp via softmax_scale=0 makes attention uniform.
    """
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 32, 4)), jnp.float32)
    cfg = MraConfig(block_size=8, blocks_per_row=4, softmax_scale=0.0)
    out = mra2_attention(q, k, v, cfg)
    uniform = jnp.broadcast_to(jnp.mean(v, axis=2, keepdims=True), v.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(uniform), atol=1e-4)


# --------------------------------------------------------------------------- #
# Decode-side invariants: the incremental pyramid and the int8 KV cache
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
       st.integers(1, 48))
def test_pyramid_incremental_append_equals_recompute(seed, block, n_tokens):
    """Incremental ``PyramidState.append`` over any position sequence is
    exactly the block sums recomputed from the cache (same fp32 adds)."""
    from repro.core.mra_decode import PyramidState

    r = np.random.default_rng(seed)
    B, Hkv, D, nb = 2, 2, 4, 4
    S = nb * block
    n = min(n_tokens, S)
    ks = r.standard_normal((B, Hkv, n, D)).astype(np.float32)
    vs = r.standard_normal((B, Hkv, n, D)).astype(np.float32)
    # per-slot ragged positions: slot b appends its first n_b tokens
    n_per = np.asarray([n, max(1, n // 2)])
    pyr = PyramidState.init(B, Hkv, nb, D)
    cache_k = np.zeros((B, Hkv, S, D), np.float32)
    cache_v = np.zeros((B, Hkv, S, D), np.float32)
    for t in range(n):
        pos = np.minimum(t, n_per - 1)  # finished slots re-write their last
        active = t < n_per
        kn = np.where(active[:, None, None], ks[:, :, t], 0.0)
        vn = np.where(active[:, None, None], vs[:, :, t], 0.0)
        for b in range(B):
            if active[b]:
                cache_k[b, :, pos[b]] = kn[b]
                cache_v[b, :, pos[b]] = vn[b]
        pyr = pyr.append(jnp.asarray(kn), jnp.asarray(vn),
                         jnp.asarray(pos), block)
    # recompute-from-cache reference (what mra2_decode_attention does when no
    # pyramid is passed)
    ref_k = cache_k.reshape(B, Hkv, nb, block, D).sum(3)
    ref_v = cache_v.reshape(B, Hkv, nb, block, D).sum(3)
    np.testing.assert_allclose(np.asarray(pyr.k_sum), ref_k, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pyr.v_sum), ref_v, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16]),
       st.integers(20, 90))
def test_ring_pyramid_update_equals_recompute_over_window(seed, block, total):
    """Ring-paged incremental updates == block sums recomputed from the live
    window, for any stream length (including multiple wraps/evictions)."""
    from repro.core.mra_decode import PyramidState, ring_pyramid_update

    r = np.random.default_rng(seed)
    B, Hkv, D, nb = 2, 2, 4, 3
    S = nb * block
    ks = r.standard_normal((B, Hkv, total, D)).astype(np.float32)
    vs = r.standard_normal((B, Hkv, total, D)).astype(np.float32)
    pyr = PyramidState.init(B, Hkv, nb, D)
    pb = jnp.full((B, nb), -1, jnp.int32)
    for p in range(total):
        pyr, pb = ring_pyramid_update(
            pyr, pb, jnp.asarray(ks[:, :, p]), jnp.asarray(vs[:, :, p]),
            jnp.full((B,), p, jnp.int32), block)
    pb_np = np.asarray(pb)
    for page in range(nb):
        blk = pb_np[0, page]
        lo, hi = blk * block, min((blk + 1) * block, total)
        np.testing.assert_allclose(
            np.asarray(pyr.k_sum)[:, :, page], ks[:, :, lo:hi].sum(2), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(pyr.v_sum)[:, :, page], vs[:, :, lo:hi].sum(2), atol=1e-5)
    # the live pages hold exactly the newest (up to nb) blocks of the stream
    expect_newest = (total - 1) // block
    assert pb_np.max() == expect_newest
    live = np.sort(pb_np[0][pb_np[0] >= 0])
    expected = np.arange(max(0, expect_newest - nb + 1), expect_newest + 1)
    np.testing.assert_array_equal(live, expected)


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_quantize_kv_roundtrip_within_int8_bound(seed, amplitude):
    """quantize -> dequantize error stays within the per-token int8 bound the
    decode path relies on: |x - x_hat| <= scale / 2 = amax / 254 per token."""
    from repro.core.mra_decode import quantize_kv

    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 3, 8, 16)) * amplitude, jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * s[..., None]
    err = np.asarray(jnp.abs(back - x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-7
    assert (err <= bound).all()


# --------------------------------------------------------------------------- #
# Speculative decoding: the accept/resample primitive (DESIGN.md §10)
# --------------------------------------------------------------------------- #
def _random_dist(r, V):
    x = r.gamma(0.7, size=V).astype(np.float64) + 1e-9
    return x / x.sum()


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
def test_spec_rejection_sampling_emits_target_distribution(seed, V):
    """The rejection-sampling identity the verify path relies on: for any
    draft distribution q, accepting d ~ q with prob min(1, p(d)/q(d)) and
    resampling rejections from norm(max(p - q, 0)) emits exactly p."""
    from repro.serve.sampling import spec_residual

    r = np.random.default_rng(seed)
    p = _random_dist(r, V)
    q = _random_dist(r, V)
    accept = np.minimum(p, q)  # q(t) * min(1, p(t)/q(t))
    p_reject = 1.0 - accept.sum()
    resid = np.exp(np.asarray(spec_residual(jnp.asarray(p), jnp.asarray(q))))
    resid = resid / resid.sum()
    emitted = accept + p_reject * resid
    np.testing.assert_allclose(emitted, p, atol=1e-6)  # fp32 residual path


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(0, 4))
def test_spec_verify_greedy_is_argmax_prefix_match(seed, K, n_match):
    """Greedy verification accepts exactly the prefix of drafts matching the
    target argmax chain and emits the target argmax at every position."""
    from repro.serve.sampling import spec_verify_batch

    r = np.random.default_rng(seed)
    V = 16
    logits = jnp.asarray(r.standard_normal((1, K + 1, V)), jnp.float32)
    argmax = np.asarray(jnp.argmax(logits[0], -1))
    n = min(n_match, K)
    draft = argmax[:K].copy()
    if n < K:  # first mismatch at position n
        draft[n] = (draft[n] + 1) % V
    out, n_out, n_acc = spec_verify_batch(
        logits, jnp.asarray(draft[None]), jnp.zeros((1, K, V)),
        jnp.zeros((1,)), jnp.zeros((1,), jnp.int32), jnp.ones((1,)),
        jnp.asarray([3], jnp.int32), jnp.asarray([5], jnp.int32),
        jnp.asarray([True]))
    assert int(n_acc[0]) == n
    assert int(n_out[0]) == n + 1
    np.testing.assert_array_equal(np.asarray(out)[0, : n + 1], argmax[: n + 1])


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_spec_verify_accepts_everything_when_draft_equals_target(seed, K):
    """q == p makes the accept probability min(1, p/q) = 1: a draft sampled
    from the target's own filtered distribution is always fully accepted,
    for any seed/temperature."""
    from repro.serve.sampling import filtered_logits, spec_verify_batch

    r = np.random.default_rng(seed)
    V = 16
    logits = jnp.asarray(r.standard_normal((1, K + 1, V)), jnp.float32)
    temp = jnp.asarray([0.8], jnp.float32)
    tk = jnp.zeros((1,), jnp.int32)
    tp = jnp.ones((1,), jnp.float32)
    q = jnp.stack([jax.nn.softmax(
        filtered_logits(logits[:, i], temp, tk, tp), -1) for i in range(K)], 1)
    # draft token i sampled from q_i itself (any in-support token works)
    draft = jnp.argmax(q, -1).astype(jnp.int32)
    _, n_out, n_acc = spec_verify_batch(
        logits, draft, q, temp, tk, tp, jnp.asarray([seed % 997], jnp.int32),
        jnp.asarray([2], jnp.int32), jnp.asarray([True]))
    assert int(n_acc[0]) == K and int(n_out[0]) == K + 1


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
def test_head_permutation_equivariance(seed, Hkv):
    """Permuting heads permutes outputs identically."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, Hkv, 32, 4)), jnp.float32)
    perm = np.asarray(np.random.default_rng(seed + 1).permutation(Hkv))
    cfg = MraConfig(block_size=8, blocks_per_row=2)
    out = mra2_attention(q, k, v, cfg)
    out_p = mra2_attention(q[:, perm], k[:, perm], v[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p), atol=1e-5)
