"""H-level pyramid conformance: collapse-up invariants + long-context serving.

Pins DESIGN.md §14 from both ends:

  * property tests (hypothesis, unquantized) — no token mass is ever lost
    (live pyramid + collapsed levels + tail telescope to the exact stream
    sum), a parent entry is exactly the sum of its children, batched
    collapse within one chunk is order-invariant, and an H=2 build is
    bit-identical to today's ring eviction with no hierarchy keys at all;
  * engine tests — an H=3 engine completes prompts far longer than its fine
    window (capacity is an admission limit only at H>=3), reports per-level
    occupancy gauges, matches the fused kernel path token-for-token, keeps
    greedy speculative decode (including ``draft_level`` 2 coarsened
    drafts) identical to plain decode, and is chunk-size invariant;
  * the serve/kv_cache.py import shim warns DeprecationWarning.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import hier
from repro.models import get_model, init_params
from repro.serve import Engine, EngineConfig, Request

# hypothesis widens the property tests when installed; without it the same
# properties run over a fixed example grid (the image may lack hypothesis,
# and a skipped invariant is no invariant — cf. test_mra_properties.py).
try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", max_examples=10, deadline=None, derandomize=True
    )
    hypothesis.settings.load_profile("ci")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _kv(seed, B, Hkv, S, D):
    r = np.random.default_rng(seed)
    k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    return k, v


def _upper_sums(cache):
    """(K_sum, V_sum) of every collapsed entry + tail: sum of mean * count."""
    up = hier.cache_upper_view(cache, 0)
    cnt = up.counts[:, None, :, None]
    return ((up.k_mean * cnt).sum(axis=2), (up.v_mean * cnt).sum(axis=2))


# (B, Hkv, S-in-blocks, D, levels) stream shapes + a seed per case
_STREAM_GRID = [
    ((1, 2, 12, 4, 3), 0),
    ((2, 1, 20, 8, 4), 1),
    ((2, 2, 8, 4, 5), 2),
    ((1, 1, 20, 4, 3), 3),
]
_ORDER_GRID = [
    (0, 3, (1, 0, 2)),
    (1, 4, (2, 1, 0)),
    (2, 3, (2, 0, 1)),
    (3, 4, (0, 2, 1)),
]

if HAVE_HYPOTHESIS:
    _streams = st.tuples(
        st.sampled_from([1, 2]),       # B
        st.sampled_from([1, 2]),       # Hkv
        st.sampled_from([8, 12, 20]),  # S in blocks
        st.sampled_from([4, 8]),       # D
        st.sampled_from([3, 4, 5]),    # levels
    )

    def stream_cases(fn):
        return given(_streams, st.integers(0, 2**31 - 1))(fn)

    def order_cases(fn):
        return given(st.integers(0, 2**31 - 1), st.sampled_from([3, 4]),
                     st.permutations([0, 1, 2]))(fn)
else:
    def stream_cases(fn):
        return pytest.mark.parametrize("shape,seed", _STREAM_GRID)(fn)

    def order_cases(fn):
        return pytest.mark.parametrize("seed,levels,perm", _ORDER_GRID)(fn)


@stream_cases
def test_total_sum_conservation(shape, seed):
    """Live pyramid + every collapsed level + tail == the exact stream sum.

    The telescoping-mass invariant behind 'distant context folds in at the
    coarsest resolution': eviction moves K/V mass up the hierarchy, never
    out of it (unquantized build; quantization error is the approx_error
    bench's dimension, not a correctness leak).
    """
    B, Hkv, nblk, D, levels = shape
    block, nb = 4, 4
    k, v = _kv(seed, B, Hkv, nblk * block, D)
    cache = hier.build_hier_stream(k, v, block=block, nb=nb, levels=levels,
                                   quantize=False)
    ks, vs = _upper_sums(cache)
    ks = ks + cache["pyr_k"][0].sum(axis=2)
    vs = vs + cache["pyr_v"][0].sum(axis=2)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(k.sum(axis=2)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(v.sum(axis=2)),
                               rtol=1e-4, atol=1e-4)


@stream_cases
def test_parent_is_sum_of_children(shape, seed):
    """A full level-2 entry's mean * count == the sum of its two fine blocks.

    Level-l entry e spans fine blocks [e*2^(l-1), (e+1)*2^(l-1)): checked at
    l=2 where both children's exact K/V are recomputable from the stream.
    """
    B, Hkv, nblk, D, levels = shape
    block, nb = 4, 4
    k, v = _kv(seed, B, Hkv, nblk * block, D)
    cache = hier.build_hier_stream(k, v, block=block, nb=nb, levels=levels,
                                   quantize=False)
    own = np.asarray(cache["hier_own2"])
    cnt = np.asarray(cache["hier_cnt2"])
    km = np.asarray(cache["hier_k2"][0]) * np.asarray(cache["hier_ks2"][0])[..., None]
    checked = 0
    for b in range(B):
        for s in range(own.shape[1]):
            if own[b, s] < 0 or cnt[b, s] != 2 * block:
                continue
            e = int(own[b, s])
            span = np.asarray(k[b, :, 2 * e * block:(2 * e + 2) * block])
            np.testing.assert_allclose(km[b, :, s] * cnt[b, s],
                                       span.sum(axis=1), rtol=1e-4, atol=1e-4)
            checked += 1
    if nblk >= 2 * nb:  # enough evictions to fill a level-2 entry
        assert checked > 0


@order_cases
def test_batched_collapse_is_order_invariant(seed, levels, perm):
    """Evictions landing in distinct level-2 slots commute.

    Within one prefill chunk (C <= window - b) the evicted blocks always
    satisfy this — the chunked path may therefore apply them in any order
    and still match sequential decode.
    """
    B, Hkv, D, block, n = 1, 2, 4, 4, 8
    r = np.random.default_rng(seed)
    # distinct level-2 entry ids and distinct slots (eid % n): blocks 2e, e<n
    blocks = [0, 6, 10]
    sums = [(jnp.asarray(r.standard_normal((B, Hkv, D)), jnp.float32),
             jnp.asarray(r.standard_normal((B, Hkv, D)), jnp.float32))
            for _ in blocks]

    def run(order):
        cache = {"tail_k": [jnp.zeros((B, Hkv, D))],
                 "tail_v": [jnp.zeros((B, Hkv, D))],
                 "tail_cnt": jnp.zeros((B,), jnp.int32)}
        for lv in range(2, levels):
            cache[f"hier_k{lv}"] = [jnp.zeros((B, Hkv, n, D))]
            cache[f"hier_v{lv}"] = [jnp.zeros((B, Hkv, n, D))]
            cache[f"hier_ks{lv}"] = [jnp.zeros((B, Hkv, n))]
            cache[f"hier_vs{lv}"] = [jnp.zeros((B, Hkv, n))]
            cache[f"hier_own{lv}"] = jnp.full((B, n), -1, jnp.int32)
            cache[f"hier_cnt{lv}"] = jnp.zeros((B, n), jnp.int32)
        on = jnp.ones((B,), bool)
        cc = jnp.full((B,), block, jnp.int32)
        for j in order:
            upd, plan = hier.cache_collapse_tables(
                cache, jnp.full((B,), blocks[j], jnp.int32), cc, on)
            cache.update(upd)
            hier.cache_store_layer(cache, 0, hier.cache_collapse_layer(
                cache, 0, plan, *sums[j], quantize=False))
        return cache

    a, b = run(range(len(blocks))), run(perm)
    for key in a:
        va = a[key][0] if isinstance(a[key], list) else a[key]
        vb = b[key][0] if isinstance(b[key], list) else b[key]
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-5, atol=1e-5, err_msg=key)


@stream_cases
def test_h2_build_matches_ring_eviction(shape, seed):
    """levels=2 is today's cache: identical fine state, no hierarchy keys."""
    B, Hkv, nblk, D, levels = shape
    block, nb = 4, 4
    k, v = _kv(seed, B, Hkv, nblk * block, D)
    two = hier.build_hier_stream(k, v, block=block, nb=nb, levels=2)
    h = hier.build_hier_stream(k, v, block=block, nb=nb, levels=levels)
    assert not hier.has_hier(two) and hier.hier_level_ids(two) == ()
    for key in ("k_cache", "v_cache", "page_blocks"):
        np.testing.assert_array_equal(np.asarray(two[key]), np.asarray(h[key]),
                                      err_msg=key)
    for key in ("pyr_k", "pyr_v"):
        np.testing.assert_array_equal(np.asarray(two[key][0]),
                                      np.asarray(h[key][0]), err_msg=key)


# --------------------------------------------------------------------------- #
# engine-level: H>=3 serving
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3-1.7b")  # mra2, block_size 16


@pytest.fixture(scope="module")
def h3cfg(cfg):
    return cfg.replace(attention=cfg.attention.replace(levels=3))


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))


def _long_reqs():
    # prompts far past the 64-token fine window; generation evicts further
    return [Request(prompt=np.arange(1, 201) % 512, max_new_tokens=8),
            Request(prompt=np.arange(3, 40), max_new_tokens=24)]


def test_h3_engine_serves_past_the_fine_window(h3cfg, params):
    """An H=3 engine completes a context much longer than max_len.

    The H=2 cache rejects this outright (admission capacity == window); at
    H>=3 capacity is None, prefill collapses evicted pages as the prompt
    streams through, and the per-level occupancy gauges report the
    collapsed mass.
    """
    eng = Engine(h3cfg, params, EngineConfig(slots=2, max_len=64, chunk=32))
    done = eng.run(_long_reqs())
    assert len(done) == 2
    for r in done:
        assert len(r.out) == r.max_new_tokens
    g = eng.telemetry.snapshot()["gauges"]
    assert g["cache_level2_entries"]["peak"] > 0
    assert g["cache_level2_tokens"]["peak"] > 0
    assert g["cache_tail_tokens"]["peak"] > 0  # 200 tokens >> window + level2
    # the fine window never grew: live tokens stay bounded by max_len
    assert g["cache_tokens_live"]["peak"] <= 64 * 2


def test_h3_engine_kernel_matches_jnp(h3cfg, params):
    """H=3 serving through the fused kernel (upper levels as resident
    tiles) emits the jnp oracle's exact tokens, both tile modes."""
    ecfg = EngineConfig(slots=2, max_len=64, chunk=32)
    ref = Engine(h3cfg, params, ecfg).run(_long_reqs())
    by = {len(r.prompt): r.out for r in ref}
    kcfg = h3cfg.replace(attn_use_kernel=True, attn_interpret=True)
    for mode in ("auto", "latency", "throughput"):
        got = Engine(kcfg, params, ecfg.replace(kernel_mode=mode)).run(
            _long_reqs())
        for r in got:
            np.testing.assert_array_equal(r.out, by[len(r.prompt)],
                                          err_msg=f"kernel_mode={mode}")


def test_h3_block_aligned_chunks_match_sequential_decode(h3cfg, params):
    """Block-aligned prefill chunks (C == block) == per-token decode replay.

    A chunk applies its evictions' collapses before attending, so within a
    *larger* chunk the resolution seam sits at the chunk start rather than
    at each block boundary (a documented DESIGN.md §14 semantic — collapsed
    tokens are always strictly older than every chunk query, but early
    queries see them one level coarser than sequential decode would). With
    C == block the chunk evicts only at its own start, which is exactly the
    sequential schedule: greedy tokens must match token-by-token replay
    bit-for-bit.
    """
    model = get_model(h3cfg)
    prompt = (np.arange(1, 201) % 512).astype(np.int32)
    n_new = 8
    eng = Engine(h3cfg, params, EngineConfig(slots=1, max_len=64, chunk=16))
    out = eng.run([Request(prompt=prompt, max_new_tokens=n_new)])[0].out

    cache = init_params(model.cache_specs(h3cfg, 1, 64), jax.random.PRNGKey(1))
    step = jax.jit(lambda c, t: model.decode_step(params, h3cfg, c, t))
    for t in prompt:
        logits, cache = step(cache, jnp.asarray([t], jnp.int32))
    oracle = []
    for _ in range(n_new):
        tok = int(jnp.argmax(jnp.where(
            jnp.arange(logits.shape[-1]) < h3cfg.vocab, logits[0], -1e9)))
        oracle.append(tok)
        logits, cache = step(cache, jnp.asarray([tok], jnp.int32))
    np.testing.assert_array_equal(out, np.array(oracle, np.int32))


def test_h3_speculative_and_draft_level_match_plain(h3cfg, params):
    """Greedy speculative H=3 serving — including the draft_level=2
    coarsened draft — emits plain decode's exact tokens, and the snapshot/
    rewind pair restores collapsed-level sums exactly (any drift would
    desync the verify chunk's background and change a token)."""
    ecfg = EngineConfig(slots=2, max_len=64, chunk=32)
    ref = Engine(h3cfg, params, ecfg).run(_long_reqs())
    by = {len(r.prompt): r.out for r in ref}
    for dl in (1, 2):
        eng = Engine(h3cfg, params, ecfg.replace(spec_k=3, draft_level=dl))
        got = eng.run(_long_reqs())
        for r in got:
            np.testing.assert_array_equal(r.out, by[len(r.prompt)],
                                          err_msg=f"draft_level={dl}")
        assert eng.stats["spec_rounds"] > 0


def test_h2_engine_unchanged_by_hier_plumbing(cfg, params):
    """levels=2 engines still reject prompts past the window (capacity is
    the admission limit) and carry no hierarchy gauges."""
    eng = Engine(cfg, params, EngineConfig(slots=1, max_len=64, chunk=32))
    with pytest.raises(ValueError, match="capacity"):
        eng.run([Request(prompt=np.arange(100), max_new_tokens=1)])
    assert "cache_level2_entries" not in eng.telemetry.snapshot()["gauges"]


def test_kv_cache_shim_warns_deprecation():
    import repro.serve.kv_cache as shim

    with pytest.warns(DeprecationWarning, match="repro.serve.kv_cache"):
        importlib.reload(shim)
    # the re-exports stay intact for existing callers
    assert shim.RingPagedKVCache is not None and shim.quantize_kv is not None


def test_draft_level_requires_divisible_pages(h3cfg, params):
    """Ring-page grouping guard: nb % 2^(draft_level-1) != 0 is a loud
    config error at dispatch, not silent misaggregation."""
    from repro.serve.speculative import draft_config

    bad = draft_config(h3cfg, draft_level=4)  # gsz 8 vs nb 4 at max_len 64
    model = get_model(bad)
    cache = init_params(model.cache_specs(bad, 1, 64), jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="draft_level"):
        model.decode_step(params, bad, cache, jnp.zeros((1,), jnp.int32))
