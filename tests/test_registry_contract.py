"""Registry serving-contract conformance: every family, one engine API.

The continuous-batching engine is lifted above the cache type by a uniform
per-layer protocol (DESIGN.md §12): each family module exposes
``cache_specs`` / ``layer_cache_kinds`` / ``prefill_chunk`` / ``decode_step``
with *identical* signatures, and the cache factory (serve/cache/) picks the
backend from the per-layer kind strings. These tests pin the contract so a
signature drift in one family fails here, not deep inside the engine.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.models import recurrentgemma, registry, rwkv6, transformer
from repro.serve import Engine, EngineConfig
from repro.serve.cache import (HybridWindowCache, RecurrentStateCache,
                               RingPagedKVCache, make_cache)

FAMILIES = {"transformer": transformer, "rwkv6": rwkv6,
            "recurrentgemma": recurrentgemma}
SERVING_API = ("cache_specs", "layer_cache_kinds", "prefill", "prefill_chunk",
               "decode_step")
KNOWN_KINDS = {"paged_kv", "kv", "wkv", "window", "rglru"}

ARCHS = {
    "transformer": "qwen3-1.7b",
    "rwkv6": "rwkv6-7b",
    "recurrentgemma": "recurrentgemma-9b",
}


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_family_exposes_serving_api(name):
    mod = FAMILIES[name]
    missing = [fn for fn in SERVING_API if not hasattr(mod, fn)]
    assert not missing, f"{name} missing serving entry points: {missing}"


@pytest.mark.parametrize("fn", SERVING_API)
@pytest.mark.parametrize("name", ["rwkv6", "recurrentgemma"])
def test_signatures_match_transformer_reference(name, fn):
    """Positional/keyword layout must be identical across families — the
    engine's jitted wrappers call every family the same way."""
    ref = inspect.signature(getattr(transformer, fn))
    got = inspect.signature(getattr(FAMILIES[name], fn))
    ref_p = [(p.name, p.kind, p.default) for p in ref.parameters.values()]
    got_p = [(p.name, p.kind, p.default) for p in got.parameters.values()]
    assert got_p == ref_p, (
        f"{name}.{fn} signature drifted from the transformer reference:\n"
        f"  reference: {ref}\n  got:       {got}")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_layer_cache_kinds_well_formed(name):
    cfg = get_smoke_config(ARCHS[name])
    kinds = get_model(cfg).layer_cache_kinds(cfg)
    assert len(kinds) == cfg.num_layers
    assert set(kinds) <= KNOWN_KINDS, kinds


@pytest.mark.parametrize("name,backend", [
    ("transformer", RingPagedKVCache),
    ("rwkv6", RecurrentStateCache),
    ("recurrentgemma", HybridWindowCache),
])
def test_cache_factory_routes_by_kinds(name, backend):
    cfg = get_smoke_config(ARCHS[name])
    model = get_model(cfg)
    cache = make_cache(cfg, model, slots=2, max_len=32)
    assert type(cache) is backend
    assert cache.kinds == tuple(model.layer_cache_kinds(cfg))
    # uniform surface regardless of backend
    assert cache.lengths.shape == (2,)
    assert isinstance(cache.paged, bool)
    if not cache.supports_spec:
        with pytest.raises(NotImplementedError):
            cache.spec_snapshot(window=4)


def test_engine_rejects_family_missing_entry_points(monkeypatch):
    """A family without the serving contract fails fast at Engine
    construction, naming what's missing."""
    class Stub:
        param_specs = staticmethod(rwkv6.param_specs)
        cache_specs = staticmethod(rwkv6.cache_specs)
        layer_cache_kinds = staticmethod(rwkv6.layer_cache_kinds)

    cfg = get_smoke_config("rwkv6-7b").replace(family="stub")
    monkeypatch.setitem(registry._FAMILIES, "stub", Stub)
    params = init_params(rwkv6.param_specs(cfg), jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="prefill_chunk"):
        Engine(cfg, params, EngineConfig(slots=1, max_len=16))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step_active_mask_freezes_slots_bitwise(name):
    """Uniform slot-isolation guarantee: with ``active`` low, a slot's cache
    rows stay bit-for-bit across a decode dispatch, in every family."""
    cfg = get_smoke_config(ARCHS[name])
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    B, S = 3, 12
    toks = np.random.default_rng(0).integers(1, cfg.vocab, (B, S)).astype(np.int32)
    cache = init_params(model.cache_specs(cfg, B, 32), jax.random.PRNGKey(1))
    _, cache = model.prefill_chunk(params, cfg, cache, jnp.asarray(toks),
                                   jnp.full((B,), S, jnp.int32))
    act = jnp.asarray([True, False, True])
    _, after = model.decode_step(params, cfg, cache,
                                 jnp.asarray([5, 6, 7], jnp.int32), active=act)

    def check(spec, a0, a1):
        # the ParamSpec axes name the batch dimension — no layout guessing
        b_axis = spec.axes.index("batch")
        frozen0 = np.asarray(jnp.take(a0, 1, axis=b_axis))
        frozen1 = np.asarray(jnp.take(a1, 1, axis=b_axis))
        assert np.array_equal(frozen0, frozen1), f"{name}: {spec} drifted"

    jax.tree.map(check, model.cache_specs(cfg, B, 32), dict(cache),
                 dict(after))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_chunk_zero_valid_is_identity(name):
    """An all-invalid chunk (num_valid == 0) must leave every cache leaf
    bit-identical — slots ride through dispatches they don't take part in."""
    cfg = get_smoke_config(ARCHS[name])
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    B = 2
    cache = init_params(model.cache_specs(cfg, B, 32), jax.random.PRNGKey(1))
    toks = np.random.default_rng(1).integers(1, cfg.vocab, (B, 8)).astype(np.int32)
    _, cache = model.prefill_chunk(params, cfg, cache, jnp.asarray(toks),
                                   jnp.full((B,), 8, jnp.int32))
    _, after = model.prefill_chunk(params, cfg, cache,
                                   jnp.zeros((B, 8), jnp.int32),
                                   jnp.zeros((B,), jnp.int32))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        dict(cache), dict(after))
