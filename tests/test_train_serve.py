"""Training loop (incl. checkpoint/restart) and serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeCfg
from repro.data import make_batch
from repro.models import get_model, init_params
from repro.optim import AdamW, cosine_schedule
from repro.serve import Engine, Request
from repro.train import TrainConfig, make_train_step, train

SHAPE = ShapeCfg("tiny", 64, 4, "train")


def test_train_step_decreases_loss():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    tc = TrainConfig(steps=8, lr=3e-3, warmup=2)
    opt = AdamW(weight_decay=0.0)
    step_fn = jax.jit(make_train_step(cfg, tc, opt, cosine_schedule(3e-3, 2, 8)))
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.slow
def test_microbatch_accumulation_matches_fullbatch():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    opt = AdamW(weight_decay=0.0)
    lr = cosine_schedule(1e-3, 1, 10)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    outs = {}
    for mb in (1, 2):
        tc = TrainConfig(microbatches=mb)
        step = jax.jit(make_train_step(cfg, tc, opt, lr))
        p, s, m = step(params, opt.init(params), batch)
        outs[mb] = (p, m)
    p1, p2 = outs[1][0], outs[2][0]
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-3


@pytest.mark.slow
def test_grad_compression_close_to_exact():
    cfg = get_smoke_config("qwen3-1.7b")
    opt = AdamW(weight_decay=0.0)
    lr = cosine_schedule(1e-3, 1, 10)
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    m_ref = jax.jit(make_train_step(cfg, TrainConfig(microbatches=2), opt, lr))(
        params, opt.init(params), batch)[2]
    m_cmp = jax.jit(make_train_step(
        cfg, TrainConfig(microbatches=2, grad_compression="bf16_ef"), opt, lr))(
        params, opt.init(params), batch)[2]
    assert abs(float(m_ref["loss"]) - float(m_cmp["loss"])) < 1e-3
    assert abs(float(m_ref["grad_norm"]) - float(m_cmp["grad_norm"])) < 0.05 * float(
        m_ref["grad_norm"]) + 1e-3


@pytest.mark.slow
def test_train_driver_checkpoint_restart(tmp_path):
    cfg = get_smoke_config("qwen3-1.7b")
    tc1 = TrainConfig(steps=4, lr=1e-3, warmup=1, ckpt_dir=str(tmp_path), ckpt_every=2,
                      log_every=100)
    p1, o1, m1 = train(cfg, SHAPE, tc1)
    # restart from step-4 checkpoint and continue to 6
    tc2 = TrainConfig(steps=6, lr=1e-3, warmup=1, ckpt_dir=str(tmp_path), ckpt_every=2,
                      log_every=100)
    p2, o2, m2 = train(cfg, SHAPE, tc2)
    assert int(o2.step) == 6
    # uninterrupted run to 6 matches the restarted one (bit-identical data)
    tc3 = TrainConfig(steps=6, lr=1e-3, warmup=1, log_every=100)
    p3, o3, m3 = train(cfg, SHAPE, tc3)
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        p2, p3)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_serve_engine_generates():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, slots=2, max_len=64)
    reqs = [Request(prompt=np.array([3, 5, 7]), max_new_tokens=4),
            Request(prompt=np.array([11, 13]), max_new_tokens=4)]
    done = eng.run(reqs)
    assert len(done) == 2
    for r in done:
        assert r.out is not None and len(r.out) == 4
        assert int(np.max(r.out)) < cfg.padded_vocab
