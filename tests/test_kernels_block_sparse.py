"""Pallas block-sparse attention kernels vs. the pure-jnp oracle (ref.py).

Sweeps shapes/dtypes/GQA groups in interpret mode (the kernel body executes
on CPU) and checks forward outputs (numerator, row sums, per-token
stabilizer) and the custom-VJP gradients. The deeper causal/GQA/padded
differential sweep lives in test_differential.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mra import MraConfig, mra2_attention
from repro.kernels.ops import block_sparse_attention
from repro.kernels.ref import block_sparse_attention_ref


def _case(rng, BHG, BHKV, n, d, b, m, dtype):
    nb = n // b
    q = jnp.asarray(rng.standard_normal((BHG, n, d)), dtype)
    k = jnp.asarray(rng.standard_normal((BHKV, n, d)), dtype)
    v = jnp.asarray(rng.standard_normal((BHKV, n, d)), dtype)
    c = jnp.asarray(rng.standard_normal((BHG, nb)), jnp.float32)
    base = np.tile(np.arange(nb), (BHG, 1))
    extra = rng.integers(0, nb, (BHG, max(m - nb, 0)))
    x_idx = jnp.asarray(np.concatenate([base, extra], 1)[:, :m], jnp.int32)
    y_idx = jnp.asarray(rng.integers(0, nb, (BHG, m)), jnp.int32)
    flags = np.ones((BHG, m), np.int32)
    flags[:, -1] = 0  # one invalid pair
    diag = np.asarray(x_idx) == np.asarray(y_idx)
    flags |= 2 * diag.astype(np.int32)
    return q, k, v, c, x_idx, y_idx, jnp.asarray(flags)


@pytest.mark.parametrize("b,d", [(8, 16), (16, 32), (32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("group", [1, 2])
def test_kernel_matches_ref(rng, b, d, dtype, group):
    BHKV = 2
    BHG = BHKV * group
    n = b * 6
    m = 8
    q, k, v, c, xi, yi, fl = _case(rng, BHG, BHKV, n, d, b, m, dtype)
    out_k, rs_k, mt_k = jax.jit(
        lambda *a: block_sparse_attention(*a, scale=0.25, block_size=b, interpret=True)
    )(q, k, v, c, xi, yi, fl)
    out_r, rs_r, mt_r = block_sparse_attention_ref(
        q, k, v, xi, yi, fl, c, scale=0.25, block_size=b
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(mt_k), np.asarray(mt_r), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(rs_k), np.asarray(rs_r), atol=tol, rtol=tol)


def test_kernel_vjp_matches_ref_autodiff(rng):
    b, d, m = 16, 32, 10
    BHKV, group = 2, 2
    BHG = BHKV * group
    n = b * 5
    q, k, v, c, xi, yi, fl = _case(rng, BHG, BHKV, n, d, b, m, jnp.float32)

    def loss_k(q, k, v, c):
        o, r, _ = block_sparse_attention(q, k, v, c, xi, yi, fl,
                                         scale=0.25, block_size=b, interpret=True)
        return jnp.sum(o * 0.3) + jnp.sum(jnp.sin(r))

    def loss_r(q, k, v, c):
        o, r, _ = block_sparse_attention_ref(q, k, v, xi, yi, fl, c,
                                             scale=0.25, block_size=b)
        return jnp.sum(o * 0.3) + jnp.sum(jnp.sin(r))

    gk = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2, 3)))(q, k, v, c)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(q, k, v, c)
    for a, bb in zip(gk, gr):
        scale = float(jnp.abs(bb).max()) + 1e-6
        assert float(jnp.abs(a - bb).max()) / scale < 1e-4
    # the stabilizer floor is gradient-transparent by contract
    assert float(jnp.abs(gk[3]).max()) == 0.0


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("variant", ["full", "sparse"])
def test_kernel_path_inside_mra_matches_jnp(rng, causal, variant):
    B, Hq, Hkv, N, D = 2, 4, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, N, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.float32)
    cfg_j = MraConfig(block_size=16, blocks_per_row=3, variant=variant, causal=causal)
    cfg_k = MraConfig(block_size=16, blocks_per_row=3, variant=variant, causal=causal,
                      use_kernel=True, interpret=True)
    oj = mra2_attention(q, k, v, cfg_j)
    ok = jax.jit(lambda a, b, c: mra2_attention(a, b, c, cfg_k))(q, k, v)
    # both paths use the same two-level per-token stabilizer — identical math
    np.testing.assert_allclose(np.asarray(oj), np.asarray(ok), atol=1e-4, rtol=1e-4)


def test_kernel_grad_through_mra(rng):
    B, Hq, Hkv, N, D = 1, 2, 1, 64, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, N, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.float32)
    cfg_k = MraConfig(block_size=16, blocks_per_row=2, use_kernel=True, interpret=True)
    cfg_j = MraConfig(block_size=16, blocks_per_row=2)
    gk = jax.grad(lambda q: mra2_attention(q, k, v, cfg_k).sum())(q)
    gj = jax.grad(lambda q: mra2_attention(q, k, v, cfg_j).sum())(q)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gj), atol=1e-4, rtol=1e-3)


def test_kernel_large_scores_no_overflow(rng):
    """Trained-model-scale scores (|s| ~ 1000) must stay finite through fwd
    AND bwd on the kernel path — the failure mode that motivated the online
    flash-style stabilizer (DESIGN.md §3)."""
    B, Hq, Hkv, N, D = 1, 2, 1, 64, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, N, D)) * 16, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, D)) * 16, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.float32)
    cfg = MraConfig(block_size=16, blocks_per_row=2, causal=True,
                    use_kernel=True, interpret=True)
    out = mra2_attention(q, k, v, cfg)
    assert bool(jnp.isfinite(out).all())
    g = jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(mra2_attention(q, k, v, cfg))),
                 argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert bool(jnp.isfinite(x).all())
