"""MoE dispatch correctness: sort+buffer formulation vs. a naive reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import MoESpec
from repro.models.moe import _expert_compute, _route, moe_block, moe_specs
from repro.models.params import init_params


def _naive_moe(x, gates, idx, wi, wg, wo):
    """Dense per-token loop reference (no capacity dropping)."""
    T, d = x.shape
    out = np.zeros((T, d), np.float32)
    xn = np.asarray(x, np.float32)
    for t in range(T):
        for j in range(idx.shape[1]):
            e = int(idx[t, j])
            h = xn[t] @ np.asarray(wi[e], np.float32)
            g = xn[t] @ np.asarray(wg[e], np.float32)
            y = (g / (1 + np.exp(-g)) * h) @ np.asarray(wo[e], np.float32)
            out[t] += float(gates[t, j]) * y
    return out


def test_expert_compute_matches_naive(rng):
    T, d, E, f, k = 24, 8, 5, 6, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((E, f, d)) * 0.3, jnp.float32)
    wr = jnp.asarray(rng.standard_normal((d, E)) * 0.3, jnp.float32)
    gates, idx, _ = _route(x, wr, MoESpec(E, k, f))
    out = _expert_compute(x, gates, idx, wi, wg, wo, e0=0, e_local=E,
                          capacity=T * k)  # capacity big enough: no drops
    ref = _naive_moe(x, np.asarray(gates), np.asarray(idx), wi, wg, wo)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_expert_slicing_partition_sums_to_whole(rng):
    """Partial expert ranges sum to the full computation (the EP-psum identity)."""
    T, d, E, f, k = 16, 8, 6, 4, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((E, f, d)) * 0.3, jnp.float32)
    wr = jnp.asarray(rng.standard_normal((d, E)) * 0.3, jnp.float32)
    gates, idx, _ = _route(x, wr, MoESpec(E, k, f))
    full = _expert_compute(x, gates, idx, wi, wg, wo, e0=0, e_local=E, capacity=T * k)
    half = E // 2
    p1 = _expert_compute(x, gates, idx, wi[:half], wg[:half], wo[:half],
                         e0=0, e_local=half, capacity=T * k)
    p2 = _expert_compute(x, gates, idx, wi[half:], wg[half:], wo[half:],
                         e0=half, e_local=half, capacity=T * k)
    np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(full),
                               atol=1e-4, rtol=1e-4)


def test_capacity_dropping_drops_not_corrupts(rng):
    T, d, E, f, k = 32, 8, 4, 4, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((E, f, d)) * 0.3, jnp.float32)
    wr = jnp.asarray(rng.standard_normal((d, E)) * 0.3, jnp.float32)
    gates, idx, _ = _route(x, wr, MoESpec(E, k, f))
    out = _expert_compute(x, gates, idx, wi, wg, wo, e0=0, e_local=E, capacity=2)
    assert bool(jnp.isfinite(out).all())
    # dropped tokens shrink norm vs. undropped, never grow it pathologically
    full = _expert_compute(x, gates, idx, wi, wg, wo, e0=0, e_local=E, capacity=T * k)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(full)) * 1.5


def test_moe_block_and_aux(rng):
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    specs = moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.bfloat16)
    out, aux = moe_block(x, p, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux["load_balance"]) > 0
    assert float(aux["router_z"]) >= 0


def test_router_gates_normalized(rng):
    d, E, k = 8, 6, 3
    x = jnp.asarray(rng.standard_normal((10, d)), jnp.float32)
    wr = jnp.asarray(rng.standard_normal((d, E)), jnp.float32)
    gates, idx, _ = _route(x, wr, MoESpec(E, k, 4))
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < E
