import os

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in a separate process; per the assignment this must NOT be global).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
