"""Serving conformance suite: the engine is pinned to the decode oracle.

Locks down the ragged continuous-batching engine (DESIGN.md §9):

  * request conformance — the batched engine's output per request is
    bit-identical to serving that request alone (same sampler seed), so
    scheduling/batching can never change what a user receives;
  * slot isolation — slots at different ragged lengths don't perturb each
    other (the old engine's per-slot prefill advanced every slot's cache);
  * chunked prefill ≡ the model's one-shot prefill and the per-token decode
    oracle; chunk attention with C == 1 ≡ the decode attention path;
  * ring-paged eviction — generation beyond the cache window keeps going
    with the window bounded;
  * sampler invariants — greedy/top-k/top-p degenerate cases, determinism,
    support restriction;
  * dispatch economy — chunked prefill issues O(ceil(P/C)) jitted dispatches
    (the serve_bench acceptance claim), empty prompts issue none;
  * TP-meshed engine ≡ single-device engine on the DP=2 x TP=4 fake mesh
    (shard marker; runs in ``scripts/ci.sh shard`` with the other parity
    tests, in a subprocess so the fake-device flag precedes jax init).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.serve import Engine, Request, SamplingParams, sample_batch

from harness import run_in_fake_mesh


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3-1.7b")  # mra2, block_size 16


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))


def _requests():
    """Ragged mix: different prompt lengths, generation lengths, samplers."""
    return [
        Request(prompt=np.arange(1, 20), max_new_tokens=6,
                sampling=SamplingParams(temperature=0.9, seed=7)),
        Request(prompt=np.array([5, 11, 2]), max_new_tokens=2,
                sampling=SamplingParams(temperature=1.0, top_k=5, seed=3)),
        Request(prompt=np.arange(2, 12), max_new_tokens=4),  # greedy
    ]


def test_engine_matches_single_request_oracle(cfg, params):
    """Batched ragged serving == one-request-at-a-time serving, bit-exact.

    Covers conformance criteria (a) and (b): per-request equivalence to the
    single-sequence decode oracle under the same sampler seed, and bit-exact
    slot isolation (any cross-slot leak in prefill or decode would show up as
    a token diff in some run).
    """
    batched = Engine(cfg, params, slots=3, max_len=64, chunk=8).run(_requests())
    assert len(batched) == 3
    by_plen = {len(r.prompt): r.out for r in batched}
    for req in _requests():
        solo = Engine(cfg, params, slots=3, max_len=64, chunk=8).run([req])[0]
        np.testing.assert_array_equal(solo.out, by_plen[len(solo.prompt)])
        assert len(solo.out) == solo.max_new_tokens


def test_engine_continuous_readmission(cfg, params):
    """More requests than slots: freed slots readmit mid-flight and every
    request still matches its solo run."""
    reqs = _requests() + [
        Request(prompt=np.arange(3, 9), max_new_tokens=5,
                sampling=SamplingParams(temperature=0.7, top_p=0.9, seed=11)),
        Request(prompt=np.array([9]), max_new_tokens=3),
    ]
    batched = Engine(cfg, params, slots=2, max_len=64, chunk=8).run(reqs)
    assert len(batched) == len(reqs)
    by_plen = {len(r.prompt): r.out for r in batched}
    for req in reqs:
        solo = Engine(cfg, params, slots=2, max_len=64, chunk=8).run(
            [Request(prompt=req.prompt, max_new_tokens=req.max_new_tokens,
                     sampling=req.sampling)])[0]
        np.testing.assert_array_equal(solo.out, by_plen[len(req.prompt)])


def test_engine_matches_prefill_decode_oracle(cfg, params):
    """Greedy engine tokens == naive prefill + per-token decode_step loop.

    Pins the chunked prefill path to the model's one-shot ``prefill`` (the
    jnp MRA prefill formulation) and ``decode_step``: same tokens out.
    """
    model = get_model(cfg)
    prompt = np.arange(1, 17).astype(np.int32)  # one full prompt, one slot
    n_new = 5
    eng = Engine(cfg, params, slots=1, max_len=64, chunk=8)
    out = eng.run([Request(prompt=prompt, max_new_tokens=n_new)])[0].out

    cache = init_params(model.cache_specs(cfg, 1, 64), jax.random.PRNGKey(1))
    logits, cache = model.prefill(params, cfg, {"tokens": jnp.asarray(prompt[None])},
                                  cache)
    oracle = []
    tok = int(jnp.argmax(jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                                   logits[0], -1e9)))
    oracle.append(tok)
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(params, cfg, cache,
                                          jnp.asarray([tok], jnp.int32))
        tok = int(jnp.argmax(jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                                       logits[0], -1e9)))
        oracle.append(tok)
    np.testing.assert_array_equal(out, np.array(oracle, np.int32))


def test_empty_and_degenerate_requests(cfg, params):
    """Empty prompts / zero-token requests complete with no spurious steps."""
    eng = Engine(cfg, params, slots=2, max_len=64, chunk=8)
    done = eng.run([Request(prompt=np.array([], np.int32), max_new_tokens=4),
                    Request(prompt=np.array([3, 4]), max_new_tokens=0)])
    assert len(done) == 2
    for r in done:
        assert r.out is not None and len(r.out) == 0
    assert eng.stats["prefill_dispatches"] == 0
    assert eng.stats["decode_dispatches"] == 0

    with pytest.raises(ValueError, match="capacity"):
        eng.run([Request(prompt=np.arange(100), max_new_tokens=1)])


def test_chunked_prefill_dispatch_economy(cfg, params):
    """ceil(P / chunk) prefill dispatches — not O(P) token replays."""
    eng = Engine(cfg, params, slots=2, max_len=64, chunk=8)
    done = eng.run([Request(prompt=np.arange(1, 25), max_new_tokens=3),
                    Request(prompt=np.arange(1, 6), max_new_tokens=3)])
    assert len(done) == 2
    assert eng.stats["prefill_dispatches"] == 3  # ceil(24 / 8)
    assert eng.stats["decode_dispatches"] <= 4
    assert eng.stats["prefill_tokens"] == 29


def test_ring_eviction_generates_past_capacity(cfg, params):
    """Generation beyond max_len evicts old background pages and keeps going;
    the page table stays a window of at most ``pages`` live blocks."""
    eng = Engine(cfg, params, slots=1, max_len=32, chunk=8)  # 2 pages of 16
    out = eng.run([Request(prompt=np.arange(1, 9), max_new_tokens=40)])[0].out
    assert len(out) == 40
    assert int(np.max(out)) < cfg.vocab
    assert eng.kv.lengths[0] == 8 + 40 - 1  # last sampled token never fed
    pb = np.asarray(eng.kv.tree["page_blocks"][0])
    assert (pb >= 0).sum() == eng.kv.pages
    assert pb.max() == (eng.kv.lengths[0] - 1) // eng.kv.block
    assert eng.kv.window_start()[0] == pb.min() * eng.kv.block


def test_sampler_degenerate_cases_equal_greedy(cfg, params):
    """top_k=1 and top_p→0 must reproduce greedy exactly, any temperature."""
    base = Engine(cfg, params, slots=1, max_len=64, chunk=8).run(
        [Request(prompt=np.arange(1, 10), max_new_tokens=5)])[0].out
    for sp in (SamplingParams(temperature=1.3, top_k=1, seed=5),
               SamplingParams(temperature=0.7, top_p=1e-6, seed=9),
               SamplingParams(temperature=1.0, top_p=0.0, seed=4)):
        out = Engine(cfg, params, slots=1, max_len=64, chunk=8).run(
            [Request(prompt=np.arange(1, 10), max_new_tokens=5, sampling=sp)]
        )[0].out
        np.testing.assert_array_equal(out, base)


def test_sampler_determinism_and_support():
    """sample_batch: per-(seed, step) determinism, slot-position independence,
    top-k support restriction, vocab-padding mask."""
    r = np.random.default_rng(0)
    logits = jnp.asarray(r.standard_normal((4, 32)), jnp.float32)
    temp = jnp.full((4,), 1.0)
    tk = jnp.full((4,), 3, jnp.int32)
    tp = jnp.ones((4,))
    seed = jnp.asarray([5, 5, 6, 5], jnp.int32)
    step = jnp.asarray([0, 0, 0, 1], jnp.int32)
    same_logits = jnp.broadcast_to(logits[0], logits.shape)
    toks = np.asarray(sample_batch(same_logits, temp, tk, tp, seed, step))
    assert toks[0] == toks[1]  # same (seed, step) -> same token, any slot
    top3 = set(np.argsort(np.asarray(same_logits[0]))[-3:].tolist())
    # 64 draws across steps stay within the top-k support
    draws = [int(np.asarray(sample_batch(
        same_logits[:1], temp[:1], tk[:1], tp[:1], seed[:1],
        jnp.asarray([i], jnp.int32)))[0]) for i in range(0, 64, 4)]
    assert set(draws) <= top3
    # vocab mask: padded columns never sampled even at huge temperature
    toks2 = np.asarray(sample_batch(
        jnp.zeros((2, 32)), jnp.full((2,), 100.0), jnp.zeros((2,), jnp.int32),
        jnp.ones((2,)), jnp.asarray([0, 1], jnp.int32),
        jnp.asarray([0, 0], jnp.int32), vocab=7))
    assert (toks2 < 7).all()


def test_chunk_attention_c1_equals_decode_attention():
    """mra2_chunk_attention with C == 1 is the decode path, numerically."""
    from repro.core.mra import MraConfig
    from repro.core.mra_decode import mra2_chunk_attention, mra2_decode_attention

    r = np.random.default_rng(2)
    B, Hq, Hkv, S, D, b = 2, 4, 2, 64, 8, 16
    k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    q = jnp.asarray(r.standard_normal((B, Hq, 1, D)), jnp.float32)
    lengths = jnp.asarray([37, 64], jnp.int32)
    mcfg = MraConfig(block_size=b, blocks_per_row=2, causal=True)
    dec = mra2_decode_attention(q, k, v, lengths, mcfg, decode_blocks=2)
    chk = mra2_chunk_attention(q, k, v, lengths, (lengths - 1)[:, None], mcfg,
                               decode_blocks=2)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(dec), atol=1e-6)


def test_full_decode_zero_length_slot_returns_zeros():
    """Regression (PR 5): ``full_decode_attention`` on a length-0 slot used to
    softmax uniformly over the finite NEG_INF sentinel and emit a garbage
    V-average; the oracles must agree — all-masked rows are zeros, exactly
    like ``full_chunk_attention`` (and the MRA paths' ``alive`` guard)."""
    from repro.core.mra_decode import full_chunk_attention, full_decode_attention

    r = np.random.default_rng(4)
    B, Hq, Hkv, S, D = 3, 4, 2, 32, 8
    q = jnp.asarray(r.standard_normal((B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    lengths = jnp.asarray([0, 17, 32], jnp.int32)
    dec = full_decode_attention(q, k, v, lengths)
    chk = full_chunk_attention(q, k, v, lengths, (lengths - 1)[:, None])
    assert float(jnp.abs(dec[0]).max()) == 0.0  # all-masked row -> zeros
    np.testing.assert_allclose(np.asarray(dec), np.asarray(chk), atol=1e-6)


def test_chunk_attention_full_budget_exact():
    """With budget >= all live pages, chunk attention == the exact oracle."""
    from repro.core.mra import MraConfig
    from repro.core.mra_decode import full_chunk_attention, mra2_chunk_attention

    r = np.random.default_rng(3)
    B, Hq, Hkv, S, D, b, C = 2, 4, 2, 64, 8, 16, 8
    k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
    q = jnp.asarray(r.standard_normal((B, Hq, C, D)), jnp.float32)
    lengths = jnp.asarray([37, 64], jnp.int32)
    q_pos = jnp.stack([jnp.arange(29, 37), jnp.arange(56, 64)])
    mcfg = MraConfig(block_size=b, blocks_per_row=2, causal=True)
    approx = mra2_chunk_attention(q, k, v, lengths, q_pos, mcfg,
                                  decode_blocks=S // b)
    exact = full_chunk_attention(q, k, v, lengths, q_pos)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(exact), atol=1e-4)


# --------------------------------------------------------------------------- #
# Resolution-speculative decoding (DESIGN.md §10)
# --------------------------------------------------------------------------- #
def test_spec_greedy_matches_nonspec_oracle(cfg, params):
    """Greedy speculative decode is bit-identical to the non-speculative
    engine for every slot of a ragged batch — including mid-stream
    rejections, readmission (more requests than slots), and ring eviction
    past max_len (the 40-token request wraps the 32-token window)."""
    def reqs():
        return [
            Request(prompt=np.arange(1, 9), max_new_tokens=40),   # evicts
            Request(prompt=np.arange(1, 20), max_new_tokens=7),
            Request(prompt=np.array([5, 11, 2]), max_new_tokens=12),
            Request(prompt=np.array([9]), max_new_tokens=3),
        ]
    base = Engine(cfg, params, slots=2, max_len=32, chunk=8).run(reqs())
    eng = Engine(cfg, params, slots=2, max_len=32, chunk=8, spec_k=4)
    got = eng.run(reqs())
    by = {len(r.prompt): r.out for r in base}
    for r in got:
        np.testing.assert_array_equal(r.out, by[len(r.prompt)])
    # the speculative path actually ran, and some drafts were rejected
    # mid-stream (an all-accepted run would not exercise the trim rewind)
    assert eng.stats["spec_rounds"] > 0
    assert eng.stats["verify_dispatches"] == eng.stats["spec_rounds"]
    assert 0 < eng.stats["spec_accepted_tokens"] < eng.stats["spec_drafted_tokens"]
    # speculation emits more tokens than it takes full-attention dispatches
    assert eng.stats["generated_tokens"] > eng.stats["verify_dispatches"]


def test_spec_sampled_batched_equals_solo_with_trace(cfg, params):
    """Sampled speculative decode: batched == solo bit-exact (the spec_key
    fold_in contract), and the fixed-seed acceptance trace is deterministic
    across runs AND across batch compositions."""
    def mk():
        return [
            Request(prompt=np.arange(1, 20), max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.9, seed=7)),
            Request(prompt=np.array([5, 11, 2]), max_new_tokens=8,
                    sampling=SamplingParams(temperature=1.0, top_k=5, seed=3)),
            Request(prompt=np.arange(2, 12), max_new_tokens=5,
                    sampling=SamplingParams(temperature=0.7, top_p=0.9,
                                            seed=11)),
        ]
    runs = [Engine(cfg, params, slots=3, max_len=64, chunk=8, spec_k=3).run(mk())
            for _ in range(2)]
    for batched in runs:
        by = {len(r.prompt): r for r in batched}
        ref = {len(r.prompt): r for r in runs[0]}
        for plen, r in by.items():
            np.testing.assert_array_equal(r.out, ref[plen].out)
            assert r.spec_accepted == ref[plen].spec_accepted
    by = {len(r.prompt): r for r in runs[0]}
    for req in mk():
        solo = Engine(cfg, params, slots=3, max_len=64, chunk=8,
                      spec_k=3).run([req])[0]
        np.testing.assert_array_equal(solo.out, by[len(solo.prompt)].out)
        assert solo.spec_accepted == by[len(solo.prompt)].spec_accepted


def test_spec_ring_rewind_restores_bit_exact(cfg, params):
    """Total rejection: snapshot -> K coarse draft steps (crossing a ring
    eviction boundary) -> rewind restores lengths, page table, pyramid AND
    the recycled pages' K/V bytes bit-exactly."""
    from repro.serve.speculative import draft_config

    model = get_model(cfg)
    eng = Engine(cfg, params, slots=2, max_len=32, chunk=8)  # 2 pages of 16
    # park slot streams just before the capacity boundary (lengths 30, 12)
    eng.run([Request(prompt=np.arange(1, 9), max_new_tokens=23),
             Request(prompt=np.arange(3, 9), max_new_tokens=7)])
    before = jax.tree.map(np.asarray, eng.kv.tree)
    act = jnp.asarray([True, True])
    snap = eng.kv.spec_snapshot(5)
    dcfg = draft_config(cfg)
    tok = jnp.asarray([7, 9], jnp.int32)
    for _ in range(4):  # slot 0 writes 30..33: evicts block 0 at pos 32
        logits, eng.kv.tree = model.decode_step(params, dcfg, eng.kv.tree,
                                                tok, active=act)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(eng.kv.lengths[0]) == 34  # the draft really advanced/evicted
    eng.kv.spec_rewind(snap, snap["lengths"], act)
    after = jax.tree.map(np.asarray, eng.kv.tree)
    jax.tree.map(np.testing.assert_array_equal, before, after)


def test_spec_rejects_non_mra_attention(cfg, params):
    """No pyramid, no draft model: spec_k on dense attention must raise."""
    dense = cfg.replace(attention=cfg.attention.replace(kind="full"))
    with pytest.raises(NotImplementedError, match="coarse"):
        Engine(dense, params, slots=1, max_len=32, chunk=8, spec_k=2)


# --------------------------------------------------------------------------- #
# TP-meshed engine parity (shard tier; DESIGN.md §8/§9)
# --------------------------------------------------------------------------- #
@pytest.mark.shard
def test_engine_tp_serving_matches_single_device():
    """The continuous-batching engine (chunked prefill + sampling + ring
    pages) generates identical tokens on the DP=2 x TP=4 fake mesh."""
    out = run_in_fake_mesh("""
        import numpy as np, jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params
        from repro.serve import Engine, Request, SamplingParams

        cfg = get_smoke_config("qwen3-1.7b", num_heads=8, kv_heads=4, head_dim=8)
        params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
        reqs = lambda: [
            Request(prompt=np.array([3, 5, 7]), max_new_tokens=4),
            Request(prompt=np.arange(2, 21), max_new_tokens=5,
                    sampling=SamplingParams(temperature=0.8, seed=13)),
            Request(prompt=np.array([11, 13]), max_new_tokens=4,
                    sampling=SamplingParams(temperature=1.0, top_k=4, seed=2)),
        ]
        ref_eng = Engine(cfg, params, slots=2, max_len=64, chunk=8)
        ref = ref_eng.run(reqs())
        mesh = make_local_mesh(2, 4)
        got = Engine(cfg.replace(attn_shard=True), params, slots=2,
                     max_len=64, chunk=8, mesh=mesh).run(reqs())
        ref_by = {len(r.prompt): r.out for r in ref}
        for r in got:
            assert np.array_equal(r.out, ref_by[len(r.prompt)]), \\
                (r.out, ref_by[len(r.prompt)])
        # 19-token prompt alone needs ceil(19/8) = 3 chunks; the other two
        # prompts ride along in shared or readmission dispatches
        assert ref_eng.stats["prefill_dispatches"] <= 4
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.shard
def test_engine_tp_spec_serving_matches_single_device():
    """Speculative serving (coarse draft + chunked verify + ring rewind)
    generates identical tokens on the DP=2 x TP=4 fake mesh (DESIGN.md §10):
    the draft AttentionSpec and the rewind's gather/scatter all partition
    under the same batch->data / kv-heads->model mapping."""
    out = run_in_fake_mesh("""
        import numpy as np, jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params
        from repro.serve import Engine, Request, SamplingParams

        cfg = get_smoke_config("qwen3-1.7b", num_heads=8, kv_heads=4, head_dim=8)
        params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
        reqs = lambda: [
            Request(prompt=np.array([3, 5, 7]), max_new_tokens=12),
            Request(prompt=np.arange(2, 21), max_new_tokens=8,
                    sampling=SamplingParams(temperature=0.8, seed=13)),
            Request(prompt=np.array([11, 13]), max_new_tokens=6),
        ]
        ref = Engine(cfg, params, slots=2, max_len=64, chunk=8,
                     spec_k=3).run(reqs())
        mesh = make_local_mesh(2, 4)
        eng = Engine(cfg.replace(attn_shard=True), params, slots=2,
                     max_len=64, chunk=8, spec_k=3, mesh=mesh)
        got = eng.run(reqs())
        ref_by = {len(r.prompt): r for r in ref}
        for r in got:
            assert np.array_equal(r.out, ref_by[len(r.prompt)].out), \\
                (r.out, ref_by[len(r.prompt)].out)
            assert r.spec_accepted == ref_by[len(r.prompt)].spec_accepted
        assert eng.stats["spec_rounds"] > 0
        print("OK")
    """)
    assert "OK" in out
