"""Sharded-vs-single-device parity on a DP=2 x TP=4 fake CPU mesh.

The device-count flag must be set before jax initializes, so these tests run
in fresh subprocesses (same pattern as test_dist_cpu.py). They pin the
DESIGN.md §8 sharding contract: attention run inside shard_map (batch over
the data axes, kv-heads over the model axis) matches the single-device path
— forward outputs, loss, and gradients — for both the pure-jnp and the
interpret-mode Pallas kernel routes, causal and padded, and for the serve
decode step over the sharded KV cache + pyramid.

Run via ``scripts/ci.sh shard`` (the fast tier deselects the ``shard``
marker; CI runs it as its own job under 8 fake host devices).
"""
import pytest

from harness import run_in_fake_mesh as _run

pytestmark = pytest.mark.shard


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "kernel"])
def test_attention_parity_causal_padded(use_kernel):
    """mra2_attention under shard_map == single device: fwd + grads.

    Sweeps causal x padded on a (2, 4) mesh with Hkv=4 (head-sharded, GQA
    group-aligned) — the Pallas kernel (interpret mode) runs per-shard with
    its custom_vjp backward.
    """
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh

        r = np.random.default_rng(0)
        B, Hq, Hkv, N, D = 4, 8, 4, 96, 16   # N pads to 128 under b=16
        q = jnp.asarray(r.standard_normal((B, Hq, N, D)), jnp.float32)
        k = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
        v = jnp.asarray(r.standard_normal((B, Hkv, N, D)), jnp.float32)
        km_full = jnp.ones((B, N), bool)
        km_pad = jnp.asarray(r.random((B, N)) > 0.25)
        mesh = make_local_mesh(2, 4)

        for causal in (False, True):
            for km in (km_full, km_pad):
                def build(shard):
                    def f(q, k, v):
                        from repro.core.attention import AttentionSpec, \\
                            self_attention
                        spec = AttentionSpec(
                            kind="mra2", block_size=16, blocks_per_row=3,
                            use_kernel={use_kernel}, interpret={use_kernel},
                            shard=shard)
                        return self_attention(q, k, v, spec, causal=causal,
                                              key_mask=km)
                    return f

                f_ref, f_sh = build(False), build(True)
                ref = jax.jit(f_ref)(q, k, v)
                with mesh_utils.use_mesh(mesh):
                    out = jax.jit(f_sh)(q, k, v)
                ferr = float(jnp.abs(out - ref).max())
                loss = lambda f: lambda q, k, v: jnp.sum(jnp.tanh(f(q, k, v)))
                gref = jax.jit(jax.grad(loss(f_ref), argnums=(0, 1, 2)))(q, k, v)
                with mesh_utils.use_mesh(mesh):
                    gsh = jax.jit(jax.grad(loss(f_sh), argnums=(0, 1, 2)))(q, k, v)
                gerr = max(float(jnp.abs(a - b).max())
                           for a, b in zip(gref, gsh))
                assert ferr < 1e-5, (causal, ferr)
                assert gerr < 1e-4, (causal, gerr)
                print("OK", causal, bool(km is km_pad), ferr, gerr)
    """)
    assert out.count("OK") == 4


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "kernel"])
def test_train_step_parity(use_kernel):
    """Model logits, loss, and grads match on the (2, 4) mesh."""
    out = _run(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeCfg
        from repro.data import make_batch
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params, param_shardings

        cfg = get_smoke_config("qwen3-1.7b", num_heads=8, kv_heads=4,
                               head_dim=8, activ_dtype="float32",
                               attn_use_kernel={use_kernel},
                               attn_interpret={use_kernel})
        model = get_model(cfg)
        shape = ShapeCfg("s", 64, 8, "train")
        batch = {{k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}}
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))

        def eval_all(c, p):
            logits, _ = model.forward(p, c, batch)
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, c, batch), has_aux=True)(p)
            return logits, loss, grads

        logits0, loss0, grads0 = jax.jit(
            lambda p: eval_all(cfg, p))(params)

        cfg_sh = cfg.replace(attn_shard=True)
        mesh = make_local_mesh(2, 4)
        p_sh = jax.tree.map(jax.device_put, params,
                            param_shardings(model.param_specs(cfg_sh), mesh))
        with mesh_utils.use_mesh(mesh):
            logits1, loss1, grads1 = jax.jit(
                lambda p: eval_all(cfg_sh, p))(p_sh)

        lerr = float(jnp.abs(logits0 - logits1).max())
        derr = abs(float(loss0) - float(loss1))
        gerr = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), grads0, grads1)))
        assert lerr < 5e-4, lerr
        assert derr < 1e-4, derr
        assert gerr < 5e-3, gerr
        print("OK", lerr, derr, gerr)
    """)
    assert "OK" in out


def test_decode_chunk_kernel_parity():
    """The fused Pallas serving kernel (kernels/chunk_attn.py, interpret
    mode) under the DP=2 x TP=4 shard_map == single device — decode and
    chunked prefill, paged ring table and int8 scales riding along, in
    both kernel modes (DESIGN.md §11: the per-shard pallas_call sees only
    its own (batch, kv-head) slice; page tables, q_pos, and the in-kernel
    selection shard over batch; ``kernel_mode`` travels inside the spec
    dataclass, so latency and throughput tiling both work unchanged
    under DP x TP)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.attention import AttentionSpec, chunk_attention, \\
            decode_attention
        from repro.core.mra_decode import quantize_kv
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh

        r = np.random.default_rng(0)
        B, Hq, Hkv, S, D, b, C = 4, 8, 4, 64, 8, 16, 8
        nb = S // b
        k = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(r.standard_normal((B, Hkv, S, D)), jnp.float32)
        q = jnp.asarray(r.standard_normal((B, Hq, C, D)), jnp.float32)
        q1 = jnp.asarray(r.standard_normal((B, Hq, 1, D)), jnp.float32)
        lengths = jnp.asarray([37, 64, 20, 55], jnp.int32)
        q_pos = jnp.maximum(lengths[:, None] - C, 0) + jnp.arange(C)
        # ring layout for two slots: 1.5x-capacity streams
        lengths_ring = jnp.asarray([96, 96, 20, 55], jnp.int32)
        pb = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[None], (B, nb))
        pb = pb.at[:2].set(jnp.roll(pb[:2] + nb // 2, nb // 2, axis=1))
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        mesh = make_local_mesh(2, 4)

        for mode in ("latency", "throughput"):
            spec = AttentionSpec(kind="mra2", block_size=b, decode_blocks=2,
                                 use_kernel=True, interpret=True,
                                 kernel_mode=mode)
            ref = jax.jit(lambda q: chunk_attention(q, k, v, lengths, q_pos,
                                                    spec))(q)
            with mesh_utils.use_mesh(mesh):
                got = jax.jit(lambda q: chunk_attention(
                    q, k, v, lengths, q_pos, spec.replace(shard=True)))(q)
            cerr = float(jnp.abs(ref - got).max())
            ref = jax.jit(lambda q: decode_attention(
                q, kq, vq, lengths_ring, spec, page_blocks=pb, k_scale=ks,
                v_scale=vs))(q1)
            with mesh_utils.use_mesh(mesh):
                got = jax.jit(lambda q: decode_attention(
                    q, kq, vq, lengths_ring, spec.replace(shard=True),
                    page_blocks=pb, k_scale=ks, v_scale=vs))(q1)
            derr = float(jnp.abs(ref - got).max())
            assert cerr < 1e-5, (mode, cerr)
            assert derr < 1e-5, (mode, derr)
            print("OK", mode, cerr, derr)
    """)
    assert out.count("OK") == 2


def test_serve_step_parity():
    """decode_step over the sharded cache (+pyramid) matches single device."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params
        from repro.models.params import init_params as build, param_shardings

        cfg = get_smoke_config("qwen3-1.7b", num_heads=8, kv_heads=4, head_dim=8,
                               activ_dtype="float32")
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        B, steps = 4, 5
        toks = np.random.default_rng(0).integers(0, cfg.vocab, (steps, B))

        def roll(c, mesh):
            specs = model.cache_specs(c, B, 64)
            cache = build(specs, jax.random.PRNGKey(0))
            p = params
            if mesh is not None:
                cache = jax.tree.map(jax.device_put, cache,
                                     param_shardings(specs, mesh))
                p = jax.tree.map(jax.device_put, params,
                                 param_shardings(model.param_specs(c), mesh))
            step = jax.jit(lambda p, cache, t: model.decode_step(p, c, cache, t))
            outs = []
            with mesh_utils.use_mesh(mesh):
                for t in toks:
                    logits, cache = step(p, cache, jnp.asarray(t, jnp.int32))
                    outs.append(logits)
            return jnp.stack(outs)

        ref = roll(cfg, None)
        mesh = make_local_mesh(2, 4)
        got = roll(cfg.replace(attn_shard=True), mesh)
        err = float(jnp.abs(ref - got).max())
        assert err < 5e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_chunk_prefill_parity():
    """prefill_chunk (ragged chunked prefill + chunk attention) over the
    sharded cache matches single device; the engine-level TP conformance
    test lives in tests/test_engine.py (same shard marker)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params
        from repro.models.params import init_params as build, param_shardings

        cfg = get_smoke_config("qwen3-1.7b", num_heads=8, kv_heads=4, head_dim=8,
                               activ_dtype="float32")
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        B, C = 4, 8
        toks = np.random.default_rng(0).integers(0, cfg.vocab, (B, 2 * C))
        nv1 = np.array([8, 3, 8, 0], np.int32)   # ragged chunk 1
        nv2 = np.array([5, 8, 0, 7], np.int32)   # ragged chunk 2

        def roll(c, mesh):
            specs = model.cache_specs(c, B, 64)
            cache = build(specs, jax.random.PRNGKey(0))
            p = params
            if mesh is not None:
                cache = jax.tree.map(jax.device_put, cache,
                                     param_shardings(specs, mesh))
                p = jax.tree.map(jax.device_put, params,
                                 param_shardings(model.param_specs(c), mesh))
            step = jax.jit(lambda p, cache, t, n: model.prefill_chunk(
                p, c, cache, t, n))
            with mesh_utils.use_mesh(mesh):
                l1, cache = step(p, cache, jnp.asarray(toks[:, :C], jnp.int32),
                                 jnp.asarray(nv1))
                l2, cache = step(p, cache, jnp.asarray(toks[:, C:], jnp.int32),
                                 jnp.asarray(nv2))
            return l1, l2, cache

        l1r, l2r, cr = roll(cfg, None)
        mesh = make_local_mesh(2, 4)
        l1s, l2s, cs = roll(cfg.replace(attn_shard=True), mesh)
        active = (np.array([nv1, nv2]) > 0)
        for (a, b), act in zip(((l1r, l1s), (l2r, l2s)), active):
            err = float(jnp.abs(a - b).max(axis=-1)[jnp.asarray(act)].max())
            assert err < 5e-4, err
        cerr = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(cr), jax.tree.leaves(cs)))
        assert cerr < 5e-4, cerr
        assert np.array_equal(np.asarray(cs["lengths"]), nv1 + nv2)
        print("OK")
    """)
    assert "OK" in out
