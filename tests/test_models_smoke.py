"""Per-architecture smoke tests: reduced config, one train step, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import ShapeCfg
from repro.data import make_batch
from repro.models import count_params, get_model, init_params

SMOKE_SHAPE = ShapeCfg("smoke", 64, 2, "train")

# jit-heavy architectures (10-20s per compile even at smoke size) live in the
# slow tier; the fast tier keeps one representative per family (dense: qwen2/
# qwen3/llama/yi, moe: granite, vlm: internvl).
_SLOW_ARCHS = {"kimi-k2-1t-a32b", "recurrentgemma-9b", "rwkv6-7b", "hubert-xlarge"}


def _arch_params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
        for a in archs
    ]


def _params_and_batch(arch, **overrides):
    cfg = get_smoke_config(arch, **overrides)
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE_SHAPE).items()}
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_forward_and_grad(arch):
    cfg, model, params, batch = _params_and_batch(arch)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), arch
    assert 0 < float(loss) < 20
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all()), arch
    logits, _ = model.forward(params, cfg, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", _arch_params(["qwen2-7b", "kimi-k2-1t-a32b",
                                               "rwkv6-7b", "recurrentgemma-9b"]))
def test_scan_layers_matches_unrolled_loss(arch):
    cfg_u = get_smoke_config(arch)
    model = get_model(cfg_u)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg_u, SMOKE_SHAPE).items()}
    cfg_s = cfg_u.replace(scan_layers=True)
    pu = init_params(model.param_specs(cfg_u), jax.random.PRNGKey(0))
    ps = init_params(model.param_specs(cfg_s), jax.random.PRNGKey(0))
    lu = float(model.loss_fn(pu, cfg_u, batch)[0])
    ls = float(model.loss_fn(ps, cfg_s, batch)[0])
    # independent inits -> only sanity-compare magnitude; exact equality is
    # covered by stacking identical weights below for one family
    assert abs(lu - ls) < 1.0


def test_scan_layers_exact_equivalence_with_stacked_weights():
    cfg_u = get_smoke_config("qwen2-7b")
    cfg_s = cfg_u.replace(scan_layers=True)
    model = get_model(cfg_u)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg_u, SMOKE_SHAPE).items()}
    pu = init_params(model.param_specs(cfg_u), jax.random.PRNGKey(0))
    ps = init_params(model.param_specs(cfg_s), jax.random.PRNGKey(0))
    ps = dict(ps)
    ps["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *pu["layers"])
    ps["embed"], ps["ln_f"] = pu["embed"], pu["ln_f"]
    lu = float(model.loss_fn(pu, cfg_u, batch)[0])
    ls = float(model.loss_fn(ps, cfg_s, batch)[0])
    assert abs(lu - ls) < 5e-3


_DECODERS = [a for a in ARCHS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", _arch_params(_DECODERS))
def test_decode_step_runs(arch):
    cfg, model, params, batch = _params_and_batch(arch)
    B = 2
    cache = init_params(model.cache_specs(cfg, B, 64), jax.random.PRNGKey(1))
    tokens = jnp.array([1, 2], jnp.int32)
    logits, cache = jax.jit(
        lambda p, c, t: model.decode_step(p, cfg, c, t)
    )(params, cache, tokens)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache["lengths"][0]) == 1
    logits2, cache = model.decode_step(params, cfg, cache, tokens)
    assert int(cache["lengths"][0]) == 2


@pytest.mark.parametrize("arch", _arch_params(["qwen3-1.7b", "rwkv6-7b",
                                               "recurrentgemma-9b"]))
def test_prefill_matches_stepwise_decode(arch):
    """Prefilling a prompt == feeding it token-by-token through decode_step."""
    cfg, model, params, _ = _params_and_batch(arch)
    if cfg.family == "dense":
        # exact-attention config for a strict equivalence check
        import dataclasses

        cfg = cfg.replace(attention=dataclasses.replace(cfg.attention, kind="full"))
    B, S = 2, 16
    toks = np.random.default_rng(3).integers(1, cfg.vocab, (B, S)).astype(np.int32)
    cache = init_params(model.cache_specs(cfg, B, 64), jax.random.PRNGKey(1))
    logits_p, cache_p = model.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, cache)

    cache_d = init_params(model.cache_specs(cfg, B, 64), jax.random.PRNGKey(1))
    for t in range(S):
        logits_d, cache_d = model.decode_step(params, cfg, cache_d, jnp.asarray(toks[:, t]))
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        atol=0.05, rtol=0.05,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_specs_build(arch):
    """The FULL configs must instantiate spec trees (no allocation) with the
    exact assigned hyperparameters."""
    cfg = get_config(arch)
    model = get_model(cfg)
    specs = model.param_specs(cfg)
    n = count_params(specs)
    assert n > 1e8, f"{arch}: {n}"
    if arch == "kimi-k2-1t-a32b":
        assert n > 0.9e12, "kimi-k2 must be ~1T params"
