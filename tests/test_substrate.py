"""Optimizer / compression / checkpoint / data / sharding-rules tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import make_batch
from repro.configs import get_smoke_config
from repro.configs.base import ShapeCfg
from repro.distributed.sharding import logical_to_pspec
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import zero_pspec
from repro.optim.compression import compress, init_ef


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #
def test_adamw_minimizes_quadratic():
    opt = AdamW(weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, gnorm = opt.update(grads, state, params, jnp.float32(0.1))
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    opt = AdamW(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    grads = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, gnorm = opt.update(grads, state, params, jnp.float32(0.1))
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < 0.2
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.1
    assert float(lr(jnp.int32(99))) < 0.2


# --------------------------------------------------------------------------- #
# compression
# --------------------------------------------------------------------------- #
def test_bf16_error_feedback_unbiased_longrun(rng):
    g = jnp.asarray(rng.standard_normal((64,)) * 1e-3, jnp.float32)
    ef = init_ef({"g": g})
    total = jnp.zeros_like(g)
    for _ in range(100):
        gq, ef = compress({"g": g}, ef)
        total = total + gq["g"].astype(jnp.float32)
    # accumulated bf16+EF sum tracks the true sum far better than raw bf16
    err_ef = float(jnp.abs(total - 100 * g).max())
    raw = sum([g.astype(jnp.bfloat16).astype(jnp.float32)] * 100, jnp.zeros_like(g))
    err_raw = float(jnp.abs(raw - 100 * g).max())
    assert err_ef <= err_raw + 1e-6
    assert err_ef < 2e-3


# --------------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_tmp_ignored(tmp_path):
    tree = {"a": jnp.zeros(2)}
    save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_9.tmp")  # simulated crash mid-write
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    tree = {"a": jnp.arange(10)}
    ck = AsyncCheckpointer()
    ck.save(str(tmp_path), 3, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #
def test_data_deterministic_per_step():
    cfg = get_smoke_config("qwen2-7b")
    shape = ShapeCfg("s", 64, 4, "train")
    b1 = make_batch(cfg, shape, step=5, seed=1)
    b2 = make_batch(cfg, shape, step=5, seed=1)
    b3 = make_batch(cfg, shape, step=6, seed=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_data_shard_disjoint():
    cfg = get_smoke_config("qwen2-7b")
    shape = ShapeCfg("s", 64, 8, "train")
    a = make_batch(cfg, shape, step=0, seed=0, shard=0, num_shards=2)
    b = make_batch(cfg, shape, step=0, seed=0, shard=1, num_shards=2)
    assert a["tokens"].shape == (4, 64)
    assert not np.array_equal(a["tokens"], b["tokens"])


# --------------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------------- #
def test_rules_divisibility_fallback():

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # heads=28 does not divide 16 -> replicated; d_ff shards
    spec = logical_to_pspec((3584, 28, 128), ("d_model", "heads", None), m)
    assert spec == jax.sharding.PartitionSpec(None, None, None)
    spec = logical_to_pspec((3584, 18944), ("d_model", "d_ff"), m)
    assert spec == jax.sharding.PartitionSpec(None, "model")
    # experts=40 does not divide -> falls to expert_ff
    spec = logical_to_pspec((40, 1536, 512), ("experts", "d_model", "expert_ff"), m)
    assert spec == jax.sharding.PartitionSpec(None, None, "model")
    # batch prefers (pod, data)
    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = logical_to_pspec((256, 4096), ("batch", None), PodMesh())
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)


def test_zero_pspec_picks_divisible_dim():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = zero_pspec((48, 1536, 512), FakeMesh())
    assert "data" in str(spec)
    spec = zero_pspec((7,), FakeMesh())
    assert spec == jax.sharding.PartitionSpec(None)
