"""Distributed-semantics tests on 8 fake CPU devices (subprocess-isolated).

The device-count flag must be set before jax initializes, so these tests run
in fresh subprocesses. They verify: (a) pjit'd train_step on a (2,4) mesh
produces the same loss as single-device, (b) MoE expert-parallel shard_map
matches the local path, (c) the full dry-run machinery works end-to-end on a
small mesh.
"""
import os
import subprocess
import sys
import textwrap


_ENV = {**os.environ, "PYTHONPATH": "src",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=_ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pjit_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeCfg
        from repro.data import make_batch
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params, param_shardings
        from repro.optim import AdamW, cosine_schedule
        from repro.train import TrainConfig, make_train_step

        cfg = get_smoke_config("qwen3-1.7b")
        model = get_model(cfg)
        shape = ShapeCfg("s", 64, 8, "train")
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        opt = AdamW()
        step = make_train_step(cfg, TrainConfig(), opt, cosine_schedule(1e-3, 1, 10))

        _, _, m_local = jax.jit(step)(params, opt.init(params), batch)

        mesh = make_local_mesh(2, 4)
        shardings = param_shardings(model.param_specs(cfg), mesh)
        p_sh = jax.tree.map(jax.device_put, params, shardings)
        with mesh_utils.use_mesh(mesh):
            _, _, m_mesh = jax.jit(step)(p_sh, opt.init(p_sh), batch)
        d = abs(float(m_local["loss"]) - float(m_mesh["loss"]))
        assert d < 5e-3, (float(m_local["loss"]), float(m_mesh["loss"]))
        print("OK", d)
    """)
    assert "OK" in out


def test_moe_expert_parallel_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models.moe import moe_block, moe_specs
        from repro.models.params import init_params, param_shardings

        cfg = get_smoke_config("kimi-k2-1t-a32b")  # 8 experts; model axis 4
        specs = moe_specs(cfg)
        p = init_params(specs, jax.random.PRNGKey(0))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, 16, cfg.d_model)), jnp.float32)
        out_local, aux_local = moe_block(x, p, cfg)

        mesh = make_local_mesh(2, 4)
        with mesh_utils.use_mesh(mesh):
            out_mesh, aux_mesh = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
        err = float(jnp.abs(out_local - out_mesh).max())
        assert err < 1e-3, err
        print("OK", err)
    """)
    assert "OK" in out


def test_mra_attention_matches_under_pjit():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.mra import MraConfig, mra2_attention
        from repro.launch.mesh import make_local_mesh

        r = np.random.default_rng(0)
        q = jnp.asarray(r.standard_normal((4, 4, 128, 16)), jnp.float32)
        k = jnp.asarray(r.standard_normal((4, 2, 128, 16)), jnp.float32)
        v = jnp.asarray(r.standard_normal((4, 2, 128, 16)), jnp.float32)
        cfg = MraConfig(block_size=16, blocks_per_row=3, causal=True)
        ref = mra2_attention(q, k, v, cfg)
        mesh = make_local_mesh(4, 2)
        sh_q = NamedSharding(mesh, P("data", "model", None, None))
        sh_kv = NamedSharding(mesh, P("data", "model", None, None))
        with mesh:
            out = jax.jit(lambda q, k, v: mra2_attention(q, k, v, cfg),
                          in_shardings=(sh_q, sh_kv, sh_kv))(q, k, v)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_dryrun_machinery_small_mesh():
    out = _run("""
        import jax
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeCfg
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.launch.specs import batch_specs, params_abstract
        from repro.optim import AdamW, cosine_schedule
        from repro.train import TrainConfig, make_train_step

        cfg = get_smoke_config("granite-moe-3b-a800m").replace(scan_layers=True)
        mesh = make_local_mesh(2, 4)
        shape = ShapeCfg("s", 64, 8, "train")
        with mesh_utils.use_mesh(mesh):
            params = params_abstract(cfg, mesh)
            opt = AdamW()
            step = make_train_step(cfg, TrainConfig(), opt, cosine_schedule(1e-3, 1, 10))
            c = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt.abstract_state(params, mesh), batch_specs(cfg, shape, mesh)
            ).compile()
            mem = c.memory_analysis()
            assert mem.temp_size_in_bytes > 0
            ca = c.cost_analysis()
            if isinstance(ca, list):  # jax 0.4.x returns one dict per computation
                ca = ca[0] if ca else {}
            print("OK", ca.get("flops", 0) > 0)
    """)
    assert "OK" in out


def test_elastic_restore_reshards(tmp_path):
    out = _run(f"""
        import jax, jax.numpy as jnp
        from repro.checkpoint import restore, save
        from repro.launch.mesh import make_local_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        save({str(tmp_path)!r}, 1, tree)
        # restore onto a different mesh/sharding than it was saved with
        mesh = make_local_mesh(2, 4)
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        back = restore({str(tmp_path)!r}, 1, tree, shardings=sh)
        assert back["w"].sharding == sh["w"]
        assert float(back["w"].sum()) == float(tree["w"].sum())
        print("OK")
    """)
    assert "OK" in out
