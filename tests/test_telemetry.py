"""Telemetry conformance suite (DESIGN.md §13).

Pins the observability contract of the serving stack:

  * typed metrics — the registry's declared-at-init discipline (undeclared
    names raise ``UndeclaredMetric``, the regression for the old ad-hoc
    ``Engine.stats`` dict any component could mint keys into), bounded
    histogram reservoirs, gauge peaks, and the ``StatsView`` compatibility
    facade;
  * observer effect — token streams are bit-identical with telemetry on vs
    off, for the plain AND the speculative engine (the clock never touches
    numerics), and the disabled path really is a no-op (no stamps, no
    reservoir growth, no trace events);
  * request-lifecycle tracing — stamps are monotonic
    (submit <= admit <= prefill_done <= first_token <= complete), TTFT
    decomposes into queue + prefill + first-decode, and the exported
    Chrome-trace event stream is well-formed (schema, monotonic ts,
    matched begin/end) and survives a JSONL round-trip;
  * occupancy — the uniform cache occupancy keys are populated for all
    three cache families (ring-paged, recurrent-state, hybrid-window).
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.serve import (Engine, EngineConfig, Request, SamplingParams,
                         UndeclaredMetric)
from repro.serve.telemetry import (MetricsRegistry, StatsView,
                                   validate_chrome_events)


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3-1.7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))


def _requests():
    """Ragged mix with readmission pressure (4 requests, 2 slots below)."""
    return [
        Request(prompt=np.arange(1, 20), max_new_tokens=6,
                sampling=SamplingParams(temperature=0.9, seed=7)),
        Request(prompt=np.array([5, 11, 2]), max_new_tokens=4),
        Request(prompt=np.arange(2, 12), max_new_tokens=5,
                sampling=SamplingParams(temperature=1.0, top_k=5, seed=3)),
        Request(prompt=np.array([9]), max_new_tokens=3),
    ]


def _engine(cfg, params, **kw):
    base = dict(slots=2, max_len=64, chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


# --------------------------------------------------------------------------- #
# typed metrics registry
# --------------------------------------------------------------------------- #
def test_undeclared_metric_raises(cfg, params):
    """Regression: components can no longer invent stats keys.

    The pre-telemetry engine's ``stats`` was a plain dict, so a typo'd or
    invented key silently forked the schema; every surface must now raise.
    """
    eng = _engine(cfg, params)
    with pytest.raises(UndeclaredMetric):
        eng.stats["invented_key"]
    with pytest.raises(UndeclaredMetric):
        eng.stats["invented_key"] = 1
    with pytest.raises(UndeclaredMetric):
        eng.telemetry.metrics.inc("invented_key")
    with pytest.raises(UndeclaredMetric):
        eng.telemetry.metrics.observe("invented_seconds", 0.1)
    # UndeclaredMetric is a KeyError: dict-era callers catching KeyError
    # (or using ``"x" in stats``) keep working
    assert issubclass(UndeclaredMetric, KeyError)
    assert "invented_key" not in eng.stats


def test_reset_stats_declares_every_writer_key(cfg, params):
    """Every counter any component writes — including the speculative keys
    SpecDecoder increments — exists (zeroed) right after reset_stats."""
    eng = _engine(cfg, params, spec_k=2)
    eng.run(_requests())
    assert eng.stats["spec_rounds"] > 0
    eng.reset_stats()
    for key in ("prefill_dispatches", "decode_dispatches", "prefill_tokens",
                "generated_tokens", "requests_completed", "spec_rounds",
                "draft_dispatches", "verify_dispatches", "spec_drafted_tokens",
                "spec_accepted_tokens", "spec_emitted_tokens"):
        assert eng.stats[key] == 0, key
    assert eng.stats["decode_step_seconds"] == []
    # and the engine can serve again with the fresh registry
    done = eng.run(_requests()[:1])
    assert len(done) == 1 and eng.stats["spec_rounds"] >= 0


def test_registry_types_and_bounds():
    m = MetricsRegistry()
    m.declare_counter("n")
    m.declare_histogram("lat", maxlen=4)
    m.declare_gauge("occ")
    with pytest.raises(ValueError, match="declared twice"):
        m.declare_counter("n")
    for i in range(10):  # reservoir stays bounded; count/sum stay exact
        m.observe("lat", float(i))
    h = m.get("lat")
    assert len(h.reservoir) == 4 and h.count == 10 and h.total == 45.0
    m.set_gauge("occ", 3.0)
    m.set_gauge("occ", 1.0)
    assert m.get("occ").value == 1.0 and m.get("occ").peak == 3.0
    with pytest.raises(TypeError, match="histogram"):
        m.inc("lat")
    view = StatsView(m)
    view["n"] += 2  # the legacy read-modify-write idiom
    assert view["n"] == 2
    with pytest.raises(TypeError, match="observe-only"):
        view["lat"] = [1.0]


def test_snapshot_json_roundtrip_and_prometheus(cfg, params):
    eng = _engine(cfg, params)
    eng.run(_requests())
    snap = eng.telemetry.snapshot()
    assert json.loads(json.dumps(snap)) == snap
    assert snap["tags"]["family"] == cfg.family
    assert snap["counters"]["requests_completed"] == 4
    for name in ("ttft_seconds", "inter_token_seconds", "queue_wait_seconds",
                 "prefill_seconds", "decode_step_seconds",
                 "prefill_chunk_seconds"):
        h = snap["histograms"][name]
        assert set(h) == {"count", "sum", "mean", "p50", "p90", "p99", "max"}
    assert snap["histograms"]["ttft_seconds"]["count"] == 4
    assert snap["histograms"]["ttft_seconds"]["p99"] > 0
    text = eng.telemetry.prometheus_text()
    assert "mra_serve_requests_completed 4" in text
    assert 'mra_serve_ttft_seconds{quantile="0.99"}' in text
    assert "mra_serve_cache_pages_live" in text


def test_prefill_dispatches_are_timed(cfg, params):
    """Satellite of §13: prefill is timed like decode, so TTFT decomposes
    into queue + prefill + first-decode with nothing unaccounted."""
    eng = _engine(cfg, params)
    eng.run(_requests())
    snap = eng.telemetry.snapshot()
    h = snap["histograms"]["prefill_chunk_seconds"]
    assert h["count"] == eng.stats["prefill_dispatches"] > 0
    assert h["sum"] > 0


# --------------------------------------------------------------------------- #
# observer effect: telemetry never changes tokens
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("spec_k", [0, 2])
def test_tokens_bit_identical_with_telemetry_on_vs_off(cfg, params, spec_k):
    on = _engine(cfg, params, spec_k=spec_k, telemetry=True).run(_requests())
    off = _engine(cfg, params, spec_k=spec_k, telemetry=False).run(_requests())
    by = {len(r.prompt): r.out for r in off}
    for r in on:
        np.testing.assert_array_equal(r.out, by[len(r.prompt)])


def test_batched_equals_solo_under_telemetry(cfg, params):
    """Batched serving with full telemetry == solo serving with it disabled:
    tracing composes with the engine's core conformance guarantee."""
    batched = _engine(cfg, params, telemetry=True).run(_requests())
    by = {len(r.prompt): r.out for r in batched}
    for req in _requests():
        solo = _engine(cfg, params, telemetry=False).run([req])[0]
        np.testing.assert_array_equal(solo.out, by[len(solo.prompt)])


def test_disabled_path_is_noop(cfg, params):
    """telemetry=False: counters keep counting (engine bookkeeping), but no
    stamps, no reservoir growth, no gauges, no trace events."""
    eng = _engine(cfg, params, telemetry=False)
    done = eng.run(_requests())
    assert eng.stats["requests_completed"] == 4
    assert eng.stats["generated_tokens"] > 0
    assert eng.stats["decode_step_seconds"] == []
    snap = eng.telemetry.snapshot()
    assert snap["histograms"]["ttft_seconds"]["count"] == 0
    assert snap["gauges"]["cache_pages_live"]["peak"] == 0.0
    assert len(eng.telemetry.trace.events) == 0
    assert all(r.trace is None for r in done)


# --------------------------------------------------------------------------- #
# request-lifecycle tracing
# --------------------------------------------------------------------------- #
def test_lifecycle_stamps_and_ttft_decomposition(cfg, params):
    eng = _engine(cfg, params)
    done = eng.run(_requests())
    for r in done:
        tr = r.trace
        assert tr is not None
        assert (tr.submit <= tr.admit <= tr.prefill_done
                <= tr.first_token <= tr.complete)
        assert len(tr.token_times) == r.max_new_tokens
        assert tr.token_times == sorted(tr.token_times)
        assert len(tr.inter_token) == r.max_new_tokens - 1
        # TTFT decomposes exactly: queue wait + prefill + first-decode gap
        parts = (tr.queue_wait + (tr.prefill_done - tr.admit)
                 + (tr.first_token - tr.prefill_done))
        assert abs(tr.ttft - parts) < 1e-9
        assert tr.ttft > 0


def test_trace_events_well_formed_and_jsonl_roundtrip(cfg, params, tmp_path):
    """The exported trace is valid Chrome-trace JSONL: schema keys present,
    timestamps monotonic, every begin matched by an end — including with
    degenerate (slotless) requests in the mix."""
    eng = _engine(cfg, params, spec_k=2)
    eng.run(_requests()
            + [Request(prompt=np.array([], np.int32), max_new_tokens=2)])
    events = eng.telemetry.trace.chrome_events()
    validate_chrome_events(events)
    names = {e["name"] for e in events}
    assert {"request", "queued", "prefill", "decode",
            "prefill_chunk", "draft", "verify"} <= names
    # request-lifecycle spans live on the slot lanes, dispatch spans on the
    # engine lane, so Perfetto shows per-slot timelines under the dispatches
    assert {e["tid"] for e in events if e["name"] == "request"} \
        <= set(range(eng.slots))
    assert all(e["tid"] == eng.telemetry.ENGINE_TID
               for e in events if e["name"] == "prefill_chunk")
    path = tmp_path / "trace.jsonl"
    n = eng.telemetry.trace.export_jsonl(str(path))
    from repro.serve.telemetry import load_trace_jsonl
    loaded = load_trace_jsonl(str(path))
    assert len(loaded) == n
    validate_chrome_events(loaded)


def test_spec_acceptance_series_per_slot(cfg, params):
    eng = _engine(cfg, params, spec_k=2)
    done = eng.run(_requests())
    series = eng.telemetry.snapshot()["series"]["spec_accept_by_slot"]
    assert series, "speculative engine recorded no per-slot acceptance"
    assert set(series) <= {str(s) for s in range(eng.slots)}
    total = sum(v for vs in series.values() for v in vs)
    assert total == eng.stats["spec_accepted_tokens"]
    # the per-request acceptance trace mirrors the slot series
    assert sum(a for r in done for a in r.trace.spec_accepts) == total


# --------------------------------------------------------------------------- #
# occupancy across the three cache families
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch,evicting", [
    ("qwen3-1.7b", True),        # RingPagedKVCache: ring eviction
    ("rwkv6-7b", False),         # RecurrentStateCache: state absorbs history
    ("recurrentgemma-9b", True),  # HybridWindowCache: window slides
])
def test_cache_occupancy_gauges_all_families(arch, evicting):
    cfg = get_smoke_config(arch)
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
    # generate past the window so the paged/hybrid backends actually evict
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=32, chunk=8))
    eng.run([Request(prompt=np.arange(1, 9), max_new_tokens=30),
             Request(prompt=np.array([3, 4, 5]), max_new_tokens=4)])
    g = eng.telemetry.snapshot()["gauges"]
    for key in ("cache_slots_active", "cache_tokens_live", "cache_pages_live",
                "cache_tokens_evicted", "slots_free", "slots_decode",
                "queue_depth"):
        assert key in g, key
    assert g["cache_slots_active"]["peak"] == 2
    assert g["cache_tokens_live"]["peak"] > 0
    assert g["slots_free"]["value"] == 2  # all drained at completion
    if evicting:
        assert g["cache_pages_live"]["peak"] > 0
        assert g["cache_tokens_evicted"]["peak"] > 0
    else:
        assert g["cache_pages_live"]["peak"] == 0.0
        assert g["cache_tokens_evicted"]["peak"] == 0.0
