"""Differential tests: Pallas kernels (interpret) vs jnp paths vs oracle.

The test-archetype core of the kernel-training PR (ISSUE 1): every risky
axis of the data-dependent sparse kernels — causal × GQA × padding ×
sparse/full — is swept through three independent implementations, forward
and backward, plus numerical VJP checks. See tests/harness.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from harness import (
    OP_SWEEP,
    SWEEP,
    grad_triple,
    make_inputs,
    make_op_inputs,
    max_rel_err,
    mra_cfg,
    op_loss,
    op_loss_normalized,
    rel_err,
    valid_rows,
)
from repro.core.mra import full_attention, mra2_attention
from repro.kernels.ops import block_sparse_attention
from repro.kernels.ref import block_sparse_attention_ref

TOL = 1e-3  # acceptance bound: pallas vs jnp ≤ 1e-3 relative (fp32)


# --------------------------------------------------------------------------- #
# Forward: kernel path vs jnp path vs exact oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", SWEEP, ids=lambda c: c.id)
def test_forward_kernel_path_matches_jnp_path(case):
    q, k, v, km = make_inputs(case)
    oj = mra2_attention(q, k, v, mra_cfg(case), key_mask=km)
    ok = jax.jit(
        lambda a, b, c: mra2_attention(a, b, c, mra_cfg(case, use_kernel=True),
                                       key_mask=km)
    )(q, k, v)
    mask = valid_rows(case, km)
    assert rel_err(ok, oj, mask) < TOL, case.id


@pytest.mark.parametrize("case", SWEEP, ids=lambda c: c.id)
def test_full_budget_matches_full_attention(case):
    """At full block budget MRA-2 is exact — both paths must hit the softmax
    oracle (the strongest cross-implementation anchor)."""
    q, k, v, km = make_inputs(case)
    nb = -(-case.N // case.block_size)
    ref = full_attention(q, k, v, causal=case.causal, key_mask=km)
    mask = valid_rows(case, km)
    for use_kernel in (False, True):
        cfg = mra_cfg(case, use_kernel=use_kernel, blocks_per_row=nb)
        out = mra2_attention(q, k, v, cfg, key_mask=km)
        assert rel_err(out, ref, mask) < 2e-3, (case.id, use_kernel)


# --------------------------------------------------------------------------- #
# Backward: fused Pallas bwd vs jnp bwd vs autodiff-through-reference
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", OP_SWEEP, ids=lambda c: c.id)
def test_op_vjp_pallas_vs_jnp_vs_autodiff(case):
    """Op-level gradient triangle, including the GQA head-grouped dk/dv
    reductions (group=2 cases) and the dc ≡ 0 stabilizer contract."""
    q, k, v, c, xi, yi, fl, km = make_op_inputs(case)

    def op(bwd_impl):
        return op_loss(
            lambda q, k, v, c: block_sparse_attention(
                q, k, v, c, xi, yi, fl, km,
                scale=0.25, block_size=case.b, interpret=True, bwd_impl=bwd_impl,
            )
        )

    ref_loss = op_loss(
        lambda q, k, v, c: block_sparse_attention_ref(
            q, k, v, xi, yi, fl, c, km, scale=0.25, block_size=case.b
        )
    )
    g_pallas = jax.jit(jax.grad(op("pallas"), argnums=(0, 1, 2, 3)))(q, k, v, c)
    g_jnp = jax.grad(op("jnp"), argnums=(0, 1, 2, 3))(q, k, v, c)
    g_auto = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(q, k, v, c)
    for name, gp, gj, ga in zip("qkvc", g_pallas, g_jnp, g_auto):
        assert max_rel_err(gp, gj) < TOL, (case.id, f"d{name} pallas vs jnp")
        assert max_rel_err(gj, ga) < TOL, (case.id, f"d{name} jnp vs autodiff")
    assert float(jnp.abs(g_pallas[3]).max()) == 0.0  # dc contract
    assert float(jnp.abs(g_auto[3]).max()) == 0.0  # ref shares the contract


@pytest.mark.parametrize("case", [OP_SWEEP[0], OP_SWEEP[5]],
                         ids=lambda c: c.id)
def test_op_numerical_vjp(case):
    """jax.test_util.check_grads: the custom VJP (fused Pallas backward)
    against numerical differentiation, on a stabilizer-invariant (normalized)
    loss — where the stop-gradient-mt contract equals the true derivative."""
    q, k, v, c, xi, yi, fl, km = make_op_inputs(case)
    w = jnp.asarray(
        np.random.default_rng(7).standard_normal(q.shape), jnp.float32
    )
    f = op_loss_normalized(
        lambda q, k, v, c: block_sparse_attention(
            q, k, v, c, xi, yi, fl, km,
            scale=0.25, block_size=case.b, interpret=True,
        ),
        w,
    )
    check_grads(f, (q, k, v, c), order=1, modes=["rev"], atol=5e-2, rtol=5e-2)


def test_stabilizer_is_gradient_transparent():
    """The c floor shifts the raw outputs (out, rowsum scale by exp(-mt)) but
    cancels in the normalized output — so dc ≡ 0 is the *correct* gradient
    for every consumer of the normalized result, not an approximation."""
    case = OP_SWEEP[0]
    q, k, v, c, xi, yi, fl, km = make_op_inputs(case)

    def normalized(c):
        o, r, _ = block_sparse_attention(
            q, k, v, c, xi, yi, fl, km,
            scale=0.25, block_size=case.b, interpret=True,
        )
        return o / r[..., None]

    # raising the floor far above every score changes out/rowsum but must
    # leave the normalized output (and hence downstream losses) unchanged
    np.testing.assert_allclose(
        np.asarray(normalized(c)), np.asarray(normalized(c + 5.0)),
        atol=1e-5, rtol=1e-5,
    )
    for impl in ("pallas", "jnp"):
        dc = jax.grad(
            lambda c: op_loss(
                lambda q, k, v, c: block_sparse_attention(
                    q, k, v, c, xi, yi, fl, km,
                    scale=0.25, block_size=case.b, interpret=True, bwd_impl=impl,
                )
            )(q, k, v, c)
        )(c)
        assert float(jnp.abs(dc).max()) == 0.0, impl


@pytest.mark.parametrize("case", [c for c in SWEEP if c.group == 2],
                         ids=lambda c: c.id)
def test_grad_parity_through_mra(case):
    """End-to-end gradient triangle through mra2_attention (selection, the
    coarse background, normalization — everything the training loss sees).

    Restricted to the GQA (group=2) half of the sweep: G=1 is a strict
    special case of the backward's per-KV-head pair flattening, and the
    op-level VJP sweep above already covers it.
    """
    q, k, v, km = make_inputs(case)

    def loss_grads(cfg):
        def loss(q, k, v):
            out = mra2_attention(q, k, v, cfg, key_mask=km)
            return jnp.sum(jnp.tanh(out))

        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    g_pallas, g_jnp, g_ref = grad_triple(case, loss_grads)
    for name, gp, gj, gr in zip("qkv", g_pallas, g_jnp, g_ref):
        # the two kernel-path backwards implement identical math
        assert max_rel_err(gp, gj) < TOL, (case.id, f"d{name} pallas vs jnp")
        # kernel path vs pure-jnp path differ only by the stabilizer choice
        assert max_rel_err(gp, gr) < 5e-3, (case.id, f"d{name} kernel vs ref")


def test_gqa_group_reduction_matches_expanded_kv():
    """dk/dv under GQA == gradients with KV heads explicitly expanded and the
    group axis summed — pins down the fused G-way reduction in the dkv pass."""
    case = OP_SWEEP[6]  # group=2, masked
    assert case.group == 2 and case.masked
    q, k, v, c, xi, yi, fl, km = make_op_inputs(case)
    G = case.group

    loss = op_loss(
        lambda q, k, v, c: block_sparse_attention(
            q, k, v, c, xi, yi, fl, km,
            scale=0.25, block_size=case.b, interpret=True,
        )
    )
    _, gk, gv, _ = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, c)

    # expanded formulation: each query head owns a private KV copy
    kx = jnp.repeat(k, G, axis=0)
    vx = jnp.repeat(v, G, axis=0)
    kmx = jnp.repeat(km, G, axis=0)
    loss_x = op_loss(
        lambda q, kx, vx, c: block_sparse_attention(
            q, kx, vx, c, xi, yi, fl, kmx,
            scale=0.25, block_size=case.b, interpret=True,
        )
    )
    _, gkx, gvx, _ = jax.grad(loss_x, argnums=(0, 1, 2, 3))(q, kx, vx, c)
    BHKV, n, d = k.shape
    np.testing.assert_allclose(
        np.asarray(gk), np.asarray(gkx.reshape(BHKV, G, n, d).sum(1)),
        atol=1e-4, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(gv), np.asarray(gvx.reshape(BHKV, G, n, d).sum(1)),
        atol=1e-4, rtol=1e-4,
    )


@pytest.mark.slow
def test_training_step_on_kernel_path():
    """One real train step with the fused kernels on (interpret): the
    kernel-path training flag end-to-end through models/train."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeCfg
    from repro.data import make_batch
    from repro.models import get_model, init_params
    from repro.optim import AdamW, cosine_schedule
    from repro.train import TrainConfig, make_train_step

    cfg = get_smoke_config("qwen2-7b")
    assert cfg.attention.kind in ("mra2", "mra2_s")
    tc = TrainConfig(steps=1, use_kernel=True, kernel_interpret=True)
    opt = AdamW()
    step = make_train_step(cfg, tc, opt, cosine_schedule(1e-3, 1, 2))
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, ShapeCfg("t", 64, 2, "train")).items()}
    params, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
