"""Differential-testing harness for the MRA-2 attention kernel stack.

Three independent implementations of the same math are compared pairwise:

  1. the Pallas kernels in interpret mode (fwd + fused bwd, the TPU path),
  2. the pure-jnp gather/scatter path (``mra2_attention`` without the
     kernel, and ``kernels/ref.py`` at the op level),
  3. exact ``full_attention`` — an oracle when the block budget covers the
     whole grid (MRA-2 at full budget is exact, paper §4).

Gradient trust comes from the same triangle: the fused Pallas backward vs
the jnp recompute backward vs autodiff through the reference forward, plus
``jax.test_util.check_grads`` (numerical VJP) on the kernel op itself.

Cases sweep causal × GQA × padding × variant — exactly the axes where a
data-dependent sparse kernel can silently go wrong (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mra import MraConfig


@dataclasses.dataclass(frozen=True)
class DiffCase:
    """One point of the differential sweep (model-level, mra2_attention)."""

    causal: bool = False
    group: int = 1  # GQA: Hq = group * Hkv
    padded: bool = False  # ragged per-batch key mask (padding traffic)
    variant: str = "full"  # MRA-2 | MRA-2-s
    B: int = 2
    Hkv: int = 2
    N: int = 56  # deliberately not a multiple of block_size
    D: int = 12
    block_size: int = 16
    blocks_per_row: int = 3
    seed: int = 0

    @property
    def Hq(self) -> int:
        return self.Hkv * self.group

    @property
    def id(self) -> str:
        return (
            f"{'causal' if self.causal else 'bidir'}-g{self.group}"
            f"-{'padded' if self.padded else 'dense'}-{self.variant}"
        )


# The sweep: every combination of the risky axes. N=56 with block_size=16
# forces sequence padding inside mra2_attention on top of the key mask.
SWEEP = [
    DiffCase(causal=c, group=g, padded=p, variant=v, seed=i)
    for i, (c, g, p, v) in enumerate(
        itertools.product([False, True], [1, 2], [False, True], ["full", "sparse"])
    )
]


def make_inputs(case: DiffCase):
    """Returns (q, k, v, key_mask) for a case; key_mask is None when dense."""
    r = np.random.default_rng(case.seed)
    q = jnp.asarray(r.standard_normal((case.B, case.Hq, case.N, case.D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((case.B, case.Hkv, case.N, case.D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((case.B, case.Hkv, case.N, case.D)), jnp.float32)
    key_mask = None
    if case.padded:
        lengths = r.integers(case.N // 2, case.N + 1, case.B)
        key_mask = jnp.asarray(np.arange(case.N)[None] < lengths[:, None])
    return q, k, v, key_mask


def mra_cfg(case: DiffCase, *, use_kernel: bool = False, kernel_bwd: str = "pallas",
            blocks_per_row: Optional[int] = None) -> MraConfig:
    return MraConfig(
        block_size=case.block_size,
        blocks_per_row=blocks_per_row or case.blocks_per_row,
        variant=case.variant,
        causal=case.causal,
        use_kernel=use_kernel,
        kernel_bwd=kernel_bwd,
        interpret=True,  # CPU validation of the TPU kernels
    )


def valid_rows(case: DiffCase, key_mask) -> jax.Array:
    """(B, 1, N, 1) mask of query rows whose output is well-defined.

    Rows at padded positions are dead in the sparse paths (zero output) but
    uniform in the softmax oracle; comparisons exclude them.
    """
    if key_mask is None:
        return jnp.ones((case.B, 1, case.N, 1), jnp.float32)
    return key_mask[:, None, :, None].astype(jnp.float32)


def rel_err(a, b, mask=None) -> float:
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if mask is not None:
        a = a * mask
        b = b * mask
    return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-9))


def max_rel_err(a, b) -> float:
    """max |a-b| / max|b| — the tolerance used for gradient parity."""
    scale = float(jnp.abs(jnp.asarray(b)).max()) + 1e-6
    return float(jnp.abs(jnp.asarray(a) - jnp.asarray(b)).max()) / scale


def grad_triple(case: DiffCase, loss_of_cfg):
    """Gradients of the same scalar loss under the three backward routes:
    (pallas-bwd kernel, jnp-bwd fallback, pure-jnp path autodiff)."""
    g_pallas = loss_of_cfg(mra_cfg(case, use_kernel=True, kernel_bwd="pallas"))
    g_jnp = loss_of_cfg(mra_cfg(case, use_kernel=True, kernel_bwd="jnp"))
    g_ref = loss_of_cfg(mra_cfg(case, use_kernel=False))
    return g_pallas, g_jnp, g_ref


# --------------------------------------------------------------------------- #
# Op-level cases (block_sparse_attention directly): exercises the kernel
# contract — flags bits, GQA row mapping, key-block masks, dc cotangent —
# without MRA's selection logic in the way.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class OpCase:
    group: int = 1
    masked: bool = False
    causal_diag: bool = False
    BHKV: int = 2
    n: int = 64
    d: int = 16
    b: int = 16
    m: int = 7
    seed: int = 0

    @property
    def id(self) -> str:
        return (
            f"g{self.group}-{'masked' if self.masked else 'dense'}"
            f"-{'tri' if self.causal_diag else 'notri'}"
        )


OP_SWEEP = [
    OpCase(group=g, masked=p, causal_diag=c, seed=i)
    for i, (g, p, c) in enumerate(
        itertools.product([1, 2], [False, True], [False, True])
    )
]


def make_op_inputs(case: OpCase):
    """Returns (q, k, v, c, x_idx, y_idx, flags, key_mask) for the raw op.

    x_idx covers every query block (the kernel contract); y_idx is random;
    one pair per row is invalid (flags bit0 = 0).
    """
    r = np.random.default_rng(case.seed)
    BHG = case.BHKV * case.group
    nb = case.n // case.b
    assert case.m >= nb
    q = jnp.asarray(r.standard_normal((BHG, case.n, case.d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((case.BHKV, case.n, case.d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((case.BHKV, case.n, case.d)), jnp.float32)
    c = jnp.asarray(r.standard_normal((BHG, nb)), jnp.float32)
    base = np.tile(np.arange(nb), (BHG, 1))
    extra = r.integers(0, nb, (BHG, case.m - nb))
    x_idx = jnp.asarray(np.concatenate([base, extra], 1), jnp.int32)
    y_idx = jnp.asarray(r.integers(0, nb, (BHG, case.m)), jnp.int32)
    flags = np.ones((BHG, case.m), np.int32)
    flags[:, -1] = 0  # one invalid pair per row
    if case.causal_diag:
        flags |= 2 * (np.asarray(x_idx) == np.asarray(y_idx)).astype(np.int32)
    key_mask = None
    if case.masked:
        key_mask = jnp.asarray(r.integers(0, 2, (case.BHKV, case.n)), jnp.int32)
    return q, k, v, c, x_idx, y_idx, jnp.asarray(flags), key_mask


def op_loss(fn):
    """Scalar loss exercising numerator and row sums with asymmetric
    cotangents, so dq, dk and dv are all nontrivial. (dc ≡ 0 by the kernel
    contract: the stabilizer is gradient-transparent.)"""

    def loss(q, k, v, c):
        o, rsum, _ = fn(q, k, v, c)
        return jnp.sum(o * 0.3) + jnp.sum(jnp.sin(rsum))

    return loss


def op_loss_normalized(fn, w):
    """Stabilizer-invariant loss: sum(w · o / rowsum). Mathematically
    independent of the per-token stabilizer mt, so the custom VJP's
    stop-gradient-mt semantics coincide with the true derivative — the loss
    to use for numerical (finite-difference) gradient checks."""

    def loss(q, k, v, c):
        o, rsum, _ = fn(q, k, v, c)
        return jnp.sum(w * o / rsum[..., None])

    return loss


# --------------------------------------------------------------------------- #
# Fake-mesh subprocess runner (shared by the shard-marker suites)
# --------------------------------------------------------------------------- #
# The fake-device flag must be set before jax initializes, so shard-parity
# tests run their payloads in fresh subprocesses under one shared env
# (tests/test_shard_parity.py, tests/test_engine.py).
import os
import subprocess
import sys
import textwrap

FAKE_MESH_ENV = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
                 "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def run_in_fake_mesh(code: str, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with 8 fake CPU devices; returns stdout."""
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=FAKE_MESH_ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout
