"""Core MRA-2 correctness: exactness invariants, masking, decode, budgets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mra import MraConfig, block_mean, full_attention, mra2_attention
from repro.core.mra_decode import (
    PyramidState,
    full_decode_attention,
    mra2_decode_attention,
)


def _qkv(rng, B=2, Hq=4, Hkv=2, N=128, D=16, dtype=jnp.float32, scale=1.0):
    q = jnp.asarray(rng.standard_normal((B, Hq, N, D)) * scale, dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, D)) * scale, dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, D)) * scale, dtype)
    return q, k, v


def _rel(a, b):
    return float(jnp.linalg.norm((a - b).astype(jnp.float32))
                 / jnp.linalg.norm(b.astype(jnp.float32)))


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("variant", ["full", "sparse"])
def test_full_budget_equals_softmax(rng, causal, variant):
    q, k, v = _qkv(rng)
    cfg = MraConfig(block_size=16, blocks_per_row=8, variant=variant, causal=causal)
    out = mra2_attention(q, k, v, cfg)
    ref = full_attention(q, k, v, causal=causal)
    assert _rel(out, ref) < 1e-5


def test_error_decreases_with_budget(rng):
    q, k, v = _qkv(rng, N=256)
    ref = full_attention(q, k, v)
    errs = []
    for bpr in (1, 2, 4, 8, 16):
        cfg = MraConfig(block_size=16, blocks_per_row=bpr)
        errs.append(_rel(mra2_attention(q, k, v, cfg), ref))
    assert errs[-1] < 1e-5  # full budget
    assert errs[0] > errs[-1]
    # monotone within small tolerance (selection is greedy, not optimal)
    for a, b in zip(errs, errs[1:]):
        assert b <= a * 1.05


def test_ragged_length_padding(rng):
    q, k, v = _qkv(rng, N=100)
    cfg = MraConfig(block_size=16, blocks_per_row=7)
    out = mra2_attention(q, k, v, cfg)
    ref = full_attention(q, k, v)
    assert _rel(out, ref) < 1e-5
    assert out.shape == ref.shape


def test_key_mask_matches_masked_full(rng):
    q, k, v = _qkv(rng, B=2, N=128)
    key_mask = jnp.asarray(rng.random((2, 128)) > 0.3)
    cfg = MraConfig(block_size=16, blocks_per_row=8)
    out = mra2_attention(q, k, v, cfg, key_mask=key_mask)
    ref = full_attention(q, k, v, key_mask=key_mask)
    assert _rel(out, ref) < 1e-5


def test_large_scores_no_nan(rng):
    """Post-RoPE-scale inputs: exp must not overflow (two-level stabilizer)."""
    q, k, v = _qkv(rng, scale=12.0)
    cfg = MraConfig(block_size=16, blocks_per_row=2, causal=True)
    out = mra2_attention(q, k, v, cfg)
    assert bool(jnp.isfinite(out).all())
    g = jax.grad(lambda q: mra2_attention(q, k, v, cfg).sum())(q)
    assert bool(jnp.isfinite(g).all())


def test_gqa_matches_expanded(rng):
    q, k, v = _qkv(rng, Hq=8, Hkv=2)
    cfg = MraConfig(block_size=16, blocks_per_row=4)
    out = mra2_attention(q, k, v, cfg)
    kx = jnp.repeat(k, 4, axis=1)
    vx = jnp.repeat(v, 4, axis=1)
    out_x = mra2_attention(q, kx, vx, cfg)
    assert _rel(out, out_x) < 1e-6


def test_value_linearity(rng):
    """A_hat does not depend on V: mra(q,k,aV) == a*mra(q,k,V)."""
    q, k, v = _qkv(rng)
    cfg = MraConfig(block_size=16, blocks_per_row=3)
    out1 = mra2_attention(q, k, 3.0 * v, cfg)
    out2 = 3.0 * mra2_attention(q, k, v, cfg)
    assert _rel(out1, out2) < 1e-6


def test_block_mean_downsample():
    x = jnp.arange(32, dtype=jnp.float32).reshape(1, 32, 1)
    ds = block_mean(x, 8)
    np.testing.assert_allclose(np.asarray(ds[0, :, 0]), [3.5, 11.5, 19.5, 27.5])


# ---------------------------------------------------------------------------- #
# decode
# ---------------------------------------------------------------------------- #
def test_decode_full_budget_exact(rng):
    B, Hq, Hkv, S, D, b = 2, 4, 2, 256, 16, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    lengths = jnp.array([201, 256])
    cfg = MraConfig(block_size=b)
    out = mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=S // b)
    ref = full_decode_attention(q, k, v, lengths)
    assert _rel(out, ref) < 1e-5


def test_decode_error_decreases(rng):
    B, Hq, Hkv, S, D, b = 2, 4, 2, 512, 16, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    lengths = jnp.array([512, 480])
    ref = full_decode_attention(q, k, v, lengths)
    cfg = MraConfig(block_size=b)
    errs = [
        _rel(mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=m), ref)
        for m in (2, 8, 32)
    ]
    assert errs[0] > errs[-1]
    assert errs[-1] < 1e-5


def test_decode_pyramid_incremental_matches_recompute(rng):
    B, Hq, Hkv, S, D, b = 2, 4, 2, 128, 16, 16
    nb = S // b
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    lengths = jnp.array([100, 128])
    pyr = PyramidState.init(B, Hkv, nb, D)
    for t in range(S):
        m = (t < lengths).astype(jnp.float32)[:, None, None]
        pos = jnp.minimum(jnp.full((B,), t), lengths - 1)
        pyr = pyr.append(k[:, :, t] * m, v[:, :, t] * m, pos, b)
    cfg = MraConfig(block_size=b)
    out_p = mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=4, pyramid=pyr)
    out_r = mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=4)
    assert _rel(out_p, out_r) < 1e-6
