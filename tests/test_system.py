"""End-to-end system test: train a tiny MRA-attention LM on the synthetic
corpus, checkpoint, restart, then serve it — the full production loop."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeCfg
from repro.serve import Engine, Request
from repro.train import TrainConfig, train

SHAPE = ShapeCfg("sys", 64, 4, "train")


@pytest.mark.slow
def test_train_checkpoint_restart_serve_end_to_end(tmp_path):
    cfg = get_smoke_config("qwen3-1.7b")  # MRA-2 attention by default
    assert cfg.attention.kind == "mra2"

    # 1) train with checkpointing; loss must improve
    losses = []
    tc = TrainConfig(steps=10, lr=3e-3, warmup=2, ckpt_dir=str(tmp_path),
                     ckpt_every=5, log_every=100)
    params, opt_state, _ = train(
        cfg, SHAPE, tc, on_metrics=lambda s, m: losses.append(m["loss"]))
    assert losses[-1] < losses[0], losses

    # 2) restart picks up the step-10 checkpoint and continues
    tc2 = TrainConfig(steps=12, lr=3e-3, warmup=2, ckpt_dir=str(tmp_path),
                      ckpt_every=5, log_every=100)
    params2, opt_state2, _ = train(cfg, SHAPE, tc2)
    assert int(opt_state2.step) == 12

    # 3) serve the trained weights through the batched engine (MRA decode)
    eng = Engine(cfg, params2, slots=2, max_len=64)
    done = eng.run([Request(prompt=np.array([5, 9, 2]), max_new_tokens=3),
                    Request(prompt=np.array([7, 7]), max_new_tokens=3)])
    assert len(done) == 2
    for r in done:
        assert len(r.out) == 3
        assert int(np.max(r.out)) < cfg.padded_vocab
