"""Beyond-paper §Perf features: head padding, int8 KV, MoE a2a, ZeRO compose."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeCfg
from repro.data import make_batch
from repro.models import get_model, init_params
from repro.optim.adamw import zero_pspec

SHAPE = ShapeCfg("s", 64, 2, "train")


def test_head_padding_zero_function_and_gradient():
    cfg_u = get_smoke_config("qwen2-7b")  # 4 heads, kv 2
    cfg_p = cfg_u.replace(pad_attn_heads_to=3)  # pads q heads 4 -> 6
    assert cfg_p.padded_heads == 6
    model = get_model(cfg_p)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg_u, SHAPE).items()}
    params = init_params(model.param_specs(cfg_p), jax.random.PRNGKey(0))
    (loss, _), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg_p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gw = grads["layers"][0]["attn"]["wq"]
    assert float(jnp.abs(gw[:, cfg_p.num_heads:]).max()) == 0.0  # dead heads
    assert float(jnp.abs(gw[:, :cfg_p.num_heads]).max()) > 0.0  # live heads


def test_int8_kv_cache_decode_quality():
    cfg_b = get_smoke_config("yi-6b")
    cfg_q = cfg_b.replace(attention=dataclasses.replace(cfg_b.attention, kv_quant=True))
    model = get_model(cfg_b)
    params = init_params(model.param_specs(cfg_b), jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = np.random.default_rng(0).integers(1, cfg_b.vocab, (B, S)).astype(np.int32)
    logits = {}
    for name, cfg in (("bf16", cfg_b), ("int8", cfg_q)):
        cache = init_params(model.cache_specs(cfg, B, 32), jax.random.PRNGKey(1))
        if name == "int8":
            assert cache["k"][0].dtype == jnp.int8
            assert "k_scale" in cache
        for t in range(S):
            lg, cache = model.decode_step(params, cfg, cache, jnp.asarray(toks[:, t]))
        logits[name] = np.asarray(lg, np.float32)
    # greedy decode robust to int8 quantization
    assert (logits["int8"].argmax(-1) == logits["bf16"].argmax(-1)).all()


def test_quantize_kv_roundtrip():
    from repro.core.mra_decode import quantize_kv

    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 3, 8, 16)) * 5,
                    jnp.float32)
    q, s = quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None]
    rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert rel < 0.01  # 1/127 per-token scale quantization


def test_zero_pspec_composes_with_param_spec():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    from jax.sharding import PartitionSpec as P

    # param already sharded over model on dim 0 (expert dim): zero must pick a
    # DIFFERENT free dim for data, never replicate over model
    spec = zero_pspec((384, 7168, 2048), FakeMesh(), base=P("model", None, None))
    assert spec[0] == "model"
    assert "data" in str(spec[1:]) or ("data",) in spec[1:]
    # fully-sharded base: no free dim -> keep base
    spec = zero_pspec((16,), FakeMesh(), base=P("model"))
    assert spec == P("model")


def test_moe_a2a_smoke_single_device():
    """a2a config falls back to local on a single device and stays correct."""
    cfg = get_smoke_config("kimi-k2-1t-a32b").replace(moe_dispatch="a2a")
    model = get_model(cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    loss, _ = model.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


def test_moe_a2a_matches_psum_on_mesh():
    import os
    import subprocess
    import sys
    import textwrap

    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.distributed import mesh_utils
        from repro.launch.mesh import make_local_mesh
        from repro.models.moe import moe_block, moe_specs
        from repro.models.params import init_params

        cfg0 = get_smoke_config("kimi-k2-1t-a32b")
        p = init_params(moe_specs(cfg0), jax.random.PRNGKey(0))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, 16, cfg0.d_model)), jnp.float32)
        mesh = make_local_mesh(2, 4)
        outs = {}
        for mode in ("psum", "a2a"):
            cfg = cfg0.replace(moe_dispatch=mode)
            with mesh_utils.use_mesh(mesh):
                out, _ = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
            outs[mode] = out
        err = float(jnp.abs(outs["a2a"] - outs["psum"]).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
