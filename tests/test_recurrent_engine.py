"""Recurrent/hybrid serving: the one engine, pointed at rwkv6 + rgemma.

Mirrors tests/test_engine.py's batched == solo conformance for the two
non-transformer families now served through the per-layer cache protocol
(DESIGN.md §12): the RecurrentStateCache (rwkv6's O(1) wkv state) and the
HybridWindowCache (recurrentgemma's RG-LRU state + sliding-window ring).
The invariants are the transformer suite's, verbatim — continuous batching,
slot readmission, ragged chunked prefill, and per-request sampling keys may
never change a request's tokens, whatever the cache backend.

The hybrid model doubles as the stress case: its local-attention ring wraps
(prompt > window) inside shared ragged dispatches while RG-LRU layers carry
state across the same chunk boundaries.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams

from harness import run_in_fake_mesh

ARCHS = ["rwkv6-7b", "recurrentgemma-9b"]


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _reqs(prompts, n_new=6):
    out = []
    for i, p in enumerate(prompts):
        sp = (SamplingParams() if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=8, seed=40 + i))
        out.append(Request(prompt=p.copy(), max_new_tokens=n_new, sampling=sp))
    return out


def test_batched_equals_solo_with_readmission(setup):
    """5 requests through 2 slots (readmission) == each alone in a 1-slot
    engine, token streams bit-exact — slot isolation + per-request sampling
    keys hold for recurrent state exactly as for paged KV."""
    cfg, model, params = setup
    # 40-token prompt wraps recurrentgemma's W=32 ring mid-batch
    prompts = _prompts(cfg, [19, 40, 3, 27, 11])
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=64, chunk=16))
    batched = _reqs(prompts)
    eng.run(batched)
    solo_eng = Engine(cfg, params, EngineConfig(slots=1, max_len=64, chunk=16))
    for i, (p, rb) in enumerate(zip(prompts, batched)):
        rs = _reqs([p], n_new=6)[0]
        rs.sampling = batched[i].sampling
        solo_eng.run([rs])
        np.testing.assert_array_equal(rb.out, rs.out)


def test_chunked_prefill_dispatch_economy(setup):
    """Chunked recurrent prefill: >= 5x fewer dispatches than token-by-token
    replay (the chunk width amortizes one dispatch over `chunk` tokens)."""
    cfg, model, params = setup
    prompts = _prompts(cfg, [30, 30, 30])
    eng = Engine(cfg, params, EngineConfig(slots=3, max_len=64, chunk=16))
    eng.run(_reqs(prompts, n_new=2))
    tokens = eng.stats["prefill_tokens"]
    dispatches = eng.stats["prefill_dispatches"]
    assert tokens == 90
    # token replay would be `tokens` dispatches; require the 5x economy
    assert dispatches * 5 <= tokens, (dispatches, tokens)


def test_unbounded_generation_past_max_len(setup):
    """State caches have no per-slot token capacity: prompt + generation
    longer than max_len serves fine (capacity is None, admission skips the
    length checks)."""
    cfg, model, params = setup
    prompts = _prompts(cfg, [40])
    eng = Engine(cfg, params, EngineConfig(slots=1, max_len=16, chunk=8))
    reqs = [Request(prompt=prompts[0], max_new_tokens=12)]
    eng.run(reqs)
    assert len(reqs[0].out) == 12


def test_default_sampling_resolution(setup):
    """EngineConfig.default_sampling applies to requests with sampling=None
    and is bit-identical to passing the same params explicitly."""
    cfg, model, params = setup
    prompts = _prompts(cfg, [13])
    sp = SamplingParams(temperature=0.7, top_k=4, seed=9)
    e1 = Engine(cfg, params,
                EngineConfig(slots=1, max_len=64, chunk=8, default_sampling=sp))
    r1 = Request(prompt=prompts[0].copy(), max_new_tokens=6)
    e1.run([r1])
    e2 = Engine(cfg, params, EngineConfig(slots=1, max_len=64, chunk=8))
    r2 = Request(prompt=prompts[0].copy(), max_new_tokens=6, sampling=sp)
    e2.run([r2])
    np.testing.assert_array_equal(r1.out, r2.out)


def test_degenerate_requests(setup):
    """Empty prompts and max_new_tokens=0 complete immediately without
    disturbing neighbours."""
    cfg, model, params = setup
    prompts = _prompts(cfg, [9])
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=64, chunk=8))
    reqs = [
        Request(prompt=np.array([], np.int32), max_new_tokens=4),
        Request(prompt=prompts[0], max_new_tokens=5),
        Request(prompt=prompts[0].copy(), max_new_tokens=0),
    ]
    eng.run(reqs)
    assert len(reqs[0].out) == 0 and len(reqs[2].out) == 0
    assert len(reqs[1].out) == 5
    # the real request is unaffected by its degenerate neighbours
    ref = Request(prompt=prompts[0].copy(), max_new_tokens=5)
    Engine(cfg, params, EngineConfig(slots=1, max_len=64, chunk=8)).run([ref])
    np.testing.assert_array_equal(reqs[1].out, ref.out)


def test_spec_decoding_rejected(setup):
    """Speculation needs the ring-paged MRA cache; recurrent backends must
    refuse spec_k at construction with a clear error."""
    cfg, model, params = setup
    with pytest.raises((NotImplementedError, ValueError)):
        Engine(cfg, params, EngineConfig(slots=1, max_len=32, spec_k=2))


def test_hybrid_window_wrap_stress():
    """recurrentgemma only: greedy generation crossing the ring-wrap point
    (len > W) inside a shared batch matches the model-level decode oracle."""
    cfg = get_smoke_config("recurrentgemma-9b")
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    prompt = _prompts(cfg, [28])[0]  # W=32: generation crosses the wrap
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=64, chunk=8))
    req = Request(prompt=prompt.copy(), max_new_tokens=10)
    decoy = Request(prompt=_prompts(cfg, [17], seed=5)[0], max_new_tokens=10)
    eng.run([req, decoy])
    # oracle: stepwise decode replay + greedy continuation, single lane
    import jax.numpy as jnp
    cache = init_params(model.cache_specs(cfg, 1, 64), jax.random.PRNGKey(1))
    for t in prompt:
        lg, cache = model.decode_step(params, cfg, cache, jnp.asarray([t]))
    toks = []
    t = int(np.argmax(lg[0]))
    for _ in range(10):
        toks.append(t)
        lg, cache = model.decode_step(params, cfg, cache, jnp.asarray([t]))
        t = int(np.argmax(lg[0]))
    np.testing.assert_array_equal(req.out, np.array(toks, np.int32))


# --------------------------------------------------------------------------- #
# DP x TP parity (shard tier; DESIGN.md §8/§12)
# --------------------------------------------------------------------------- #
@pytest.mark.shard
@pytest.mark.parametrize("arch_name", ARCHS)
def test_recurrent_engine_dp_tp_parity(arch_name):
    """Recurrent/hybrid serving on the DP=2 x TP=4 fake mesh generates the
    same tokens as single-device: state caches and the RG-LRU block place
    over the batch axis only (DESIGN.md §12 — the recurrence is elementwise,
    so w-sharding would only buy psum'd partial contractions whose
    reassociated bf16 rounding drifts from single-device), and the hybrid
    window attention shard_maps over batch (MQA kv_heads=1 leaves the model
    axis replicated).

    The shared MLP / attention projections keep their TP psums, which round
    at bf16 exactly as in the transformer parity suite — so, as there, the
    greedy prompts are chosen with top-1/top-2 logit gaps well above 1 ulp
    (the untrained smoke models have near-degenerate argmax ties on many
    inputs; a tie at 1 ulp is a coin flip under any TP reduction order)."""
    out = run_in_fake_mesh(f"""
        import numpy as np, jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model, init_params
        from repro.serve import Engine, EngineConfig, Request, SamplingParams

        cfg = get_smoke_config("{arch_name}")
        params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
        reqs = lambda: [
            Request(prompt=np.array([4, 8, 15]), max_new_tokens=4),
            Request(prompt=np.arange(2, 38) % cfg.vocab, max_new_tokens=5,
                    sampling=SamplingParams(temperature=0.8, seed=13)),
            Request(prompt=np.array([14, 27]), max_new_tokens=4),
        ]
        ref = Engine(cfg, params, EngineConfig(slots=2, max_len=64, chunk=8)).run(reqs())
        mesh = make_local_mesh(2, 4)
        got = Engine(cfg.replace(attn_shard=True), params,
                     EngineConfig(slots=2, max_len=64, chunk=8, mesh=mesh)).run(reqs())
        ref_by = {{len(r.prompt): r.out for r in ref}}
        for r in got:
            assert np.array_equal(r.out, ref_by[len(r.prompt)]), \\
                (r.out, ref_by[len(r.prompt)])
        print("OK")
    """)
    assert "OK" in out
