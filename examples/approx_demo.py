"""Reproduce the paper's Fig. 1 narrative: MRA vs low-rank vs sparsity.

Builds a representative (structured) attention matrix, approximates it three
ways at the same 10% budget, and prints the error comparison the paper opens
with (MRA 0.30 / low-rank 1.24 / sparse 0.39 on their example).

    PYTHONPATH=src python examples/approx_demo.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.approx_error import fig1_matrix_level  # noqa: E402


def main():
    print("budget = keep 10% of {MRA block entries | ranks | nonzeros}\n")
    print(f"{'seed':>4} {'MRA':>8} {'SVD(opt)':>9} {'Nystrom':>9} {'sparse*':>8}")
    errs = []
    for seed in range(5):
        e = fig1_matrix_level(np.random.default_rng(seed))
        errs.append(e)
        print(f"{seed:>4} {e[0]:8.3f} {e[1]:9.3f} {e[2]:9.3f} {e[3]:8.3f}")
    mean = np.mean(errs, axis=0)
    print(f"{'mean':>4} {mean[0]:8.3f} {mean[1]:9.3f} {mean[2]:9.3f} {mean[3]:8.3f}")
    print("\npaper Fig. 1: MRA 0.30, low-rank 1.24, sparse 0.39")
    print("(* top-entry sparsity is an O(n^2) oracle, not a practical method;")
    print("   SVD is the optimal low-rank bound; Nystrom is the realizable one)")
    print("claim check — MRA < practical low-rank:", bool(mean[0] < mean[2]))
    print("claim check — MRA < optimal SVD:       ", bool(mean[0] < mean[1]))


if __name__ == "__main__":
    main()
