"""End-to-end driver: train a small causal LM with MRA-2 attention and compare
against exact-softmax attention on the same data.

Default preset trains a ~15M-param model for a few hundred steps on the
synthetic corpus (CPU-feasible); --preset full is the 100M-class config for
real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax

from repro.configs.base import ModelConfig, ShapeCfg
from repro.core.attention import AttentionSpec
from repro.train import TrainConfig, train

PRESETS = {
    # ~15M params: CPU-runnable end-to-end demo
    "small": dict(num_layers=4, d_model=256, num_heads=8, kv_heads=4, d_ff=1024,
                  vocab=8192, head_dim=32, seq=256, batch=8),
    # ~110M params: the "train ~100M for a few hundred steps" driver (device-sized)
    "full": dict(num_layers=12, d_model=768, num_heads=12, kv_heads=12, d_ff=3072,
                 vocab=32768, head_dim=64, seq=1024, batch=32),
}


def build_cfg(p, kind: str) -> ModelConfig:
    return ModelConfig(
        name=f"train-lm-{kind}",
        family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"], num_heads=p["num_heads"],
        kv_heads=p["kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        head_dim=p["head_dim"],
        attention=AttentionSpec(kind=kind, block_size=32, blocks_per_row=4),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--attention", default="mra2,full",
                    help="comma-separated attention kinds to train")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route MRA attention through the fused Pallas "
                         "fwd+bwd kernels (interpret mode off-TPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (data x model; default 1 = "
                         "single device; attention shards via shard_map)")
    args = ap.parse_args()
    interpret = jax.devices()[0].platform != "tpu"
    from repro.launch.mesh import parse_mesh
    mesh = parse_mesh(args.mesh)

    p = PRESETS[args.preset]
    shape = ShapeCfg("train", p["seq"], p["batch"], "train")
    curves = {}
    for kind in args.attention.split(","):
        cfg = build_cfg(p, kind)
        tc = TrainConfig(steps=args.steps, lr=1e-3, warmup=20, log_every=20,
                         ckpt_dir=args.ckpt_dir and f"{args.ckpt_dir}/{kind}",
                         use_kernel=args.use_kernel or None,
                         kernel_interpret=args.use_kernel and interpret,
                         shard_attention=True if mesh is not None else None)
        hist = []
        print(f"=== training with attention={kind} ===")
        train(cfg, shape, tc, mesh=mesh,
              on_metrics=lambda s, m: hist.append(m["loss"]))
        curves[kind] = hist

    print("\nfinal losses:")
    for kind, hist in curves.items():
        k = max(len(hist) // 10, 1)
        print(f"  {kind:8s} start={sum(hist[:k])/k:.4f} "
              f"final={sum(hist[-k:])/k:.4f}")
    if "mra2" in curves and "full" in curves:
        k = max(len(curves["mra2"]) // 10, 1)
        gap = sum(curves["mra2"][-k:]) / k - sum(curves["full"][-k:]) / k
        print(f"  MRA-2 vs full final-loss gap: {gap:+.4f} "
              "(paper Tab. 2: MRA-2 trains on par with softmax attention)")


if __name__ == "__main__":
    main()
