"""Quickstart: MRA-2 attention as a drop-in module.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttentionSpec, MraConfig, full_attention, mra2_attention, self_attention


def main():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, N, D = 2, 8, 2, 1024, 64  # GQA: 8 query heads share 2 KV heads
    q = jnp.asarray(rng.standard_normal((B, Hq, N, D)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, D)), jnp.bfloat16)

    # 1) direct: the paper's MRA-2 with R={32, 1}, budget 4 blocks/row
    cfg = MraConfig(block_size=32, blocks_per_row=4)
    out = jax.jit(lambda q, k, v: mra2_attention(q, k, v, cfg))(q, k, v)
    ref = full_attention(q, k, v)
    err = float(jnp.linalg.norm((out - ref).astype(jnp.float32))
                / jnp.linalg.norm(ref.astype(jnp.float32)))
    print(f"MRA-2 (b=32, 4 blocks/row)  rel error vs softmax: {err:.4f}")

    # 2) budget sweep: accuracy/cost dial of the paper (Tab. 7)
    for bpr in (1, 2, 8, 16):
        c = MraConfig(block_size=32, blocks_per_row=bpr)
        o = mra2_attention(q, k, v, c)
        e = float(jnp.linalg.norm((o - ref).astype(jnp.float32))
                  / jnp.linalg.norm(ref.astype(jnp.float32)))
        frac = c.budget(N) * 32 * 32 / (N * N)
        print(f"  blocks/row={bpr:>2}  entries kept={frac:5.1%}  rel err={e:.4f}")

    # 3) through the model-facing dispatch (what the architectures use)
    spec = AttentionSpec(kind="mra2", block_size=32, blocks_per_row=4)
    out2 = self_attention(q, k, v, spec, causal=True)
    print("dispatch (causal mra2):", out2.shape, out2.dtype)

    # 4) the Pallas TPU kernel path, validated in interpret mode on CPU
    cfg_k = MraConfig(block_size=32, blocks_per_row=4, use_kernel=True, interpret=True)
    out3 = mra2_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), cfg_k)
    print("kernel path max |diff| vs jnp path:",
          float(jnp.abs(out3 - mra2_attention(q.astype(jnp.float32),
                                              k.astype(jnp.float32),
                                              v.astype(jnp.float32),
                                              MraConfig(block_size=32, blocks_per_row=4))).max()))


if __name__ == "__main__":
    main()
