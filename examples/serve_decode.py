"""Batched serving with MRA decode: top-k KV-block selection per new token.

Loads a (randomly initialized or checkpointed) model and serves a batch of
requests through the continuous-batching engine — chunked prefill, ragged
slots, per-request sampling — then compares MRA decode against exact decode
attention on the same prompts (greedy mode).

    PYTHONPATH=src python examples/serve_decode.py
    PYTHONPATH=src python examples/serve_decode.py --temperature 0.8 --seed 7
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams

RECURRENT_ARCHS = ("rwkv6-7b", "recurrentgemma-9b")


def main():
    ap = argparse.ArgumentParser()
    # the continuous-batching engine serves every registered family through
    # the per-layer cache protocol (DESIGN.md §12): transformer archs get the
    # paged KV cache and the MRA-vs-exact comparison below; recurrent archs
    # (rwkv6, recurrentgemma) serve through their state caches (one pass, no
    # attention-kind comparison — rwkv6 has no attention to approximate)
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=["qwen3-1.7b", "qwen2-7b", "llama3.2-3b", "yi-6b",
                             "kimi-k2-1t-a32b", "granite-moe-3b-a800m",
                             *RECURRENT_ARCHS])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size (tokens per slot per dispatch)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples (top-k/top-p below)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="request sampling seed (req i uses seed + i)")
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (data x model; default 1 = "
                         "single device; TP decode via shard_map)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft length (0 = plain decode; MRA "
                         "kinds only — the pyramid is the draft model, "
                         "DESIGN.md §10)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route MRA chunk/decode attention through the fused "
                         "Pallas serving kernel (DESIGN.md §11; interpret "
                         "mode off-TPU — slow on CPU, same tokens)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the serving engine's request-lifecycle + "
                         "dispatch trace as Chrome-trace JSONL (load in "
                         "chrome://tracing or Perfetto; DESIGN.md §13)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the engine's Prometheus-format telemetry "
                         "snapshot (TTFT/inter-token/queue histograms, "
                         "dispatch counters, occupancy gauges) after the run")
    args = ap.parse_args()
    from repro.launch.mesh import parse_mesh
    mesh = parse_mesh(args.mesh)

    def dump_telemetry(eng):
        """Export the engine's observability surfaces (DESIGN.md §13)."""
        if args.metrics:
            print(eng.telemetry.prometheus_text(), end="")
        if args.trace:
            n = eng.telemetry.trace.export_jsonl(args.trace)
            print(f"wrote {n} Chrome-trace events to {args.trace} "
                  "(open in chrome://tracing or ui.perfetto.dev)")

    def make_requests(cfg):
        rng = np.random.default_rng(0)
        return [Request(prompt=rng.integers(1, cfg.vocab, size=ln),
                        max_new_tokens=args.new_tokens,
                        sampling=SamplingParams(
                            temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed + i))
                for i, ln in enumerate((5, 9, 13, 7))]

    if args.arch in RECURRENT_ARCHS:
        # recurrent/hybrid serving: same engine, state cache backend;
        # speculation and the MRA serving kernel are paged-KV-only paths
        if args.spec_k or args.use_kernel:
            ap.error("--spec-k/--use-kernel need the MRA paged-KV cache "
                     "(transformer archs)")
        cfg = get_smoke_config(args.arch).replace(attn_shard=mesh is not None)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        if args.ckpt_dir:
            step = latest_step(args.ckpt_dir)
            if step is not None:
                params = restore(args.ckpt_dir, step, params)
                print(f"restored checkpoint step {step}")
        eng = Engine(cfg, params, EngineConfig(
            slots=4, max_len=128, chunk=args.chunk, mesh=mesh))
        done = eng.run(make_requests(cfg))
        print(f"[{args.arch}] generated "
              f"({eng.stats['prefill_dispatches']} prefill + "
              f"{eng.stats['decode_dispatches']} decode dispatches):")
        for r in done:
            print(f"  req ({len(r.prompt)} prompt toks) -> {r.out.tolist()}")
        dump_telemetry(eng)
        return

    outs = {}
    for kind in ("mra2", "full"):
        cfg = get_smoke_config(args.arch)
        # the serving kernel is an MRA path; the exact-attention reference
        # engine always runs the dense jnp oracle
        use_kernel = args.use_kernel and kind.startswith("mra")
        cfg = cfg.replace(attention=dataclasses.replace(
            cfg.attention, kind=kind, decode_blocks=2),
            attn_shard=mesh is not None,
            attn_use_kernel=use_kernel,
            attn_interpret=use_kernel
            and jax.devices()[0].platform != "tpu")
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        if args.ckpt_dir:
            step = latest_step(args.ckpt_dir)
            if step is not None:
                params = restore(args.ckpt_dir, step, params)
                print(f"restored checkpoint step {step}")
        # speculation needs the MRA pyramid; the exact-attention reference
        # engine always decodes plainly
        spec_k = args.spec_k if kind.startswith("mra") else 0
        eng = Engine(cfg, params, EngineConfig(
            slots=4, max_len=128, chunk=args.chunk, spec_k=spec_k, mesh=mesh))
        done = eng.run(make_requests(cfg))
        outs[kind] = {len(r.prompt): r.out.tolist() for r in done}
        spec_note = ""
        if spec_k:
            st = eng.stats
            rate = st["spec_accepted_tokens"] / max(st["spec_drafted_tokens"], 1)
            spec_note = (f" + {st['draft_dispatches']} draft + "
                         f"{st['verify_dispatches']} verify; "
                         f"accept rate {rate:.2f}")
        print(f"[{kind}] generated "
              f"({eng.stats['prefill_dispatches']} prefill + "
              f"{eng.stats['decode_dispatches']} decode dispatches"
              f"{spec_note}):")
        for r in done:
            print(f"  req ({len(r.prompt)} prompt toks) -> {r.out.tolist()}")
        if kind.startswith("mra"):
            # the MRA engine (speculative when --spec-k) is the interesting
            # trace; the exact-attention reference is just the oracle
            dump_telemetry(eng)

    keys = sorted(outs["full"])
    agree = sum(int(outs["mra2"][k] == outs["full"][k]) for k in keys)
    mode = "greedy argmax" if args.temperature <= 0 else "seeded sampling"
    print(f"\nMRA decode vs exact decode: {agree}/{len(keys)} "
          f"sequences identical ({mode} robustness to approximation)")


if __name__ == "__main__":
    main()
