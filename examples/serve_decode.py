"""Batched serving with MRA decode: top-k KV-block selection per new token.

Loads a (randomly initialized or checkpointed) model, serves a batch of
requests through the continuous-batching engine, and compares MRA decode
against exact decode attention on the same prompts.

    PYTHONPATH=src python examples/serve_decode.py
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import get_smoke_config
from repro.models import get_model, init_params
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (data x model; default 1 = "
                         "single device; TP decode via shard_map)")
    args = ap.parse_args()
    from repro.launch.mesh import parse_mesh
    mesh = parse_mesh(args.mesh)

    outs = {}
    for kind in ("mra2", "full"):
        cfg = get_smoke_config(args.arch)
        cfg = cfg.replace(attention=dataclasses.replace(
            cfg.attention, kind=kind, decode_blocks=2),
            attn_shard=mesh is not None)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        if args.ckpt_dir:
            step = latest_step(args.ckpt_dir)
            if step is not None:
                params = restore(args.ckpt_dir, step, params)
                print(f"restored checkpoint step {step}")
        eng = Engine(cfg, params, slots=4, max_len=128, mesh=mesh)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=ln),
                        max_new_tokens=args.new_tokens)
                for ln in (5, 9, 13, 7)]
        done = eng.run(reqs)
        outs[kind] = [r.out.tolist() for r in done]
        print(f"[{kind}] generated:")
        for i, r in enumerate(done):
            print(f"  req{i} ({len(r.prompt)} prompt toks) -> {r.out.tolist()}")

    agree = sum(int(a == b) for a, b in zip(outs["mra2"], outs["full"]))
    print(f"\nMRA decode vs exact decode: {agree}/{len(outs['full'])} "
          "sequences identical (greedy argmax robustness to approximation)")


if __name__ == "__main__":
    main()
