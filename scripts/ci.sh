#!/usr/bin/env bash
# CI smoke: install dev deps (best effort — the offline container already
# bakes in jax/pytest), then run the fast test tier on CPU. The Pallas
# kernels run in interpret mode inside the tests (tests/test_differential.py,
# tests/test_kernels_block_sparse.py), so the TPU fwd+bwd path is exercised
# end-to-end on every CPU run.
#
# Usage:
#   scripts/ci.sh          # fast tier (default: pytest -m "not slow")
#   scripts/ci.sh slow     # the slow tier only
#   scripts/ci.sh all      # everything
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  # offline containers skip this cleanly; hypothesis-only tests importorskip
  pip install --retries 0 --timeout 5 -r requirements-dev.txt \
    || echo "[ci] dev-dep install skipped (offline?)"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

case "${1:-fast}" in
  fast) python -m pytest -x -q ;;                # pytest.ini deselects slow
  slow) python -m pytest -x -q -m slow ;;
  all)  python -m pytest -x -q -m "" ;;
  *)    echo "usage: scripts/ci.sh [fast|slow|all]" >&2; exit 2 ;;
esac
