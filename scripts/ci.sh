#!/usr/bin/env bash
# CI tiers: install dev deps (best effort — the offline container already
# bakes in jax/pytest), then run the requested tier on CPU. The Pallas
# kernels run in interpret mode inside the tests — training fwd+bwd
# (tests/test_differential.py, tests/test_kernels_block_sparse.py) and the
# fused chunk/decode serving kernel (tests/test_chunk_kernel.py, DESIGN.md
# §11), whose in-kernel top-m selection differential subset runs in BOTH
# tile modes (latency single-query + throughput multi-query MXU tiles,
# test_kernel_forced_modes_match_jnp / test_kernel_oversubscribed_budget)
# and stays interpret-mode-bounded (small nb, C <= 5) so the fast tier's
# wall time holds — so both TPU paths are exercised end-to-end on every
# CPU run. The
# fast tier also pins the cross-family serving contract: registry signature
# conformance (tests/test_registry_contract.py) and the recurrent/hybrid
# engine's batched == solo guarantees (tests/test_recurrent_engine.py,
# DESIGN.md §12). The shard tier re-runs the training/serving stack, serving
# kernel included, under 8 fake host devices (tests/test_shard_parity.py,
# plus the recurrent-engine DP x TP parity in tests/test_recurrent_engine.py).
#
# Usage:
#   scripts/ci.sh          # fast tier (default: pytest -m "not slow and not shard")
#   scripts/ci.sh lint     # ruff check + format check (skipped if ruff missing)
#   scripts/ci.sh shard    # sharded-vs-single-device parity on 8 fake devices
#   scripts/ci.sh slow     # the slow tier only
#   scripts/ci.sh all      # everything
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  # offline containers skip this cleanly; hypothesis-only tests importorskip
  pip install --retries 0 --timeout 5 -r requirements-dev.txt \
    || echo "[ci] dev-dep install skipped (offline?)"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

case "${1:-fast}" in
  fast)
    python -m pytest -x -q                       # pytest.ini deselects slow+shard
    # telemetry smoke (DESIGN.md §13): metrics snapshot round-trips through
    # JSON, reservoirs stay bounded, trace events validate as Chrome-trace
    # (imported form avoids runpy's found-in-sys.modules warning)
    python -c "from repro.serve import telemetry; telemetry._selftest()"
    # speculative-decoding smoke (DESIGN.md §10): K=2, tiny model, jnp paths
    # (kernels stay in interpret-capable territory on the decode side)
    python -m benchmarks.spec_bench --smoke
    # H-level long-context smoke (DESIGN.md §14): an H=3 engine streams a
    # context 8x its fine window through the interpret-mode serving kernel,
    # collapsing evicted pages up the hierarchy (asserts per-level occupancy
    # + bounded live window internally)
    python -m benchmarks.serve_bench --long-ctx-smoke
    ;;
  lint)
    # tracked bytecode is a repo-hygiene regression (76 .pyc files were once
    # committed by accident); fail fast if it ever reappears
    if git -C . rev-parse --git-dir >/dev/null 2>&1; then
      TRACKED_PYC=$(git ls-files -- '*.pyc' '**/__pycache__/**' | head -5)
      if [ -n "$TRACKED_PYC" ]; then
        echo "[ci] FAIL: compiled bytecode is tracked by git:" >&2
        echo "$TRACKED_PYC" >&2
        exit 1
      fi
    fi
    if python -m ruff --version >/dev/null 2>&1; then RUFF="python -m ruff";
    elif command -v ruff >/dev/null 2>&1; then RUFF="ruff";
    else
      echo "[ci] ruff not installed; lint tier skipped (offline container)"
      exit 0
    fi
    $RUFF check .
    # Format drift is reported, not gating, until the tree has been formatted
    # once with a pinned ruff (the repo predates the formatter; blind-gating
    # would red the job on style the linter can auto-fix with `ruff format`).
    $RUFF format --diff . || echo "[ci] ruff format drift (non-gating; run 'ruff format .')"
    ;;
  shard)
    # The parity tests spawn their own subprocesses with the device-count
    # flag; exporting it here also covers any future in-process mesh tests.
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    python -m pytest -x -q -m shard
    ;;
  slow) python -m pytest -x -q -m slow ;;
  all)  python -m pytest -x -q -m "" ;;
  *)    echo "usage: scripts/ci.sh [fast|lint|shard|slow|all]" >&2; exit 2 ;;
esac
