"""Model / run configuration schema shared by every architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.attention import AttentionSpec


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv6 | hubert | recurrentgemma | internvl
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    causal: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    pos: str = "rope"  # rope | learned | none
    rope_theta: float = 10000.0
    max_seq: int = 8192  # learned-positions table size
    moe: Optional[MoESpec] = None
    attention: AttentionSpec = dataclasses.field(default_factory=AttentionSpec)
    # Pallas kernel routing for the MRA attention layers. When
    # attn_use_kernel is set, cfg.attn_spec overrides the AttentionSpec's
    # kernel fields so train/serve entry points can flip the fused kernel
    # path (fwd + bwd) on without rebuilding the spec. attn_interpret runs
    # the kernels in interpret mode (CPU CI); attn_kernel_bwd selects the
    # backward implementation ("pallas" fused kernels | "jnp" fallback).
    attn_use_kernel: bool = False
    attn_interpret: bool = False
    attn_kernel_bwd: str = "pallas"
    # serving-kernel dispatch mode (DESIGN.md §11): "auto" lets each jitted
    # entry point pick at trace time (decode_step -> latency single-query
    # tiles, prefill_chunk -> throughput multi-query tiles); "latency" /
    # "throughput" force one tile shape for every dispatch.
    attn_kernel_mode: str = "auto"
    # Mesh-sharded attention: run every attention layer inside a shard_map
    # over the active mesh (batch -> data axes, kv-heads -> model axis).
    # Required for the Pallas kernel path on a mesh (XLA cannot partition
    # through a pallas_call); a no-op without an active mesh (DESIGN.md §8).
    attn_shard: bool = False
    # hybrid (recurrentgemma): repeating block pattern
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "local")
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 16  # keeps the factored chunk form exact in fp32
    decay_lora: int = 64
    # modality frontends (stubs per assignment: precomputed embeddings in)
    frontend: Optional[str] = None  # audio_frames | vision_patches
    frontend_dim: int = 512
    num_patches: int = 0
    # numerics / execution
    pad_vocab_to: int = 256  # embedding table padded so vocab shards over TP
    # §Perf optimization (off in the paper-faithful baseline): pad the query
    # heads to a multiple of this and expand KV to this many slots so the
    # whole attention block shards over the model axis even when the real
    # head counts don't divide it (qwen2 28H, llama 24H, internvl 14H).
    # Padded heads are hard-masked before the output projection (zero
    # function + zero gradient), so the effective arch keeps its exact
    # head count.
    pad_attn_heads_to: int = 0
    # MoE dispatch (§Perf K iterations): "psum" = replicated tokens + local
    # expert slice + psum (simple, more collective bytes); "a2a" = sequence-
    # sharded tokens exchanged via all_to_all to expert owners and back
    # (production EP; falls back to psum when seq doesn't divide the axis).
    moe_dispatch: str = "psum"
    param_dtype: str = "float32"
    activ_dtype: str = "bfloat16"
    scan_layers: bool = False
    remat: str = "none"  # none | full | dots
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_spec(self) -> AttentionSpec:
        """cfg.attention with the model-level kernel/mesh routing applied."""
        spec = self.attention
        if self.attn_use_kernel:
            spec = dataclasses.replace(
                spec,
                use_kernel=True,
                interpret=self.attn_interpret,
                kernel_bwd=self.attn_kernel_bwd,
                kernel_mode=self.attn_kernel_mode,
            )
        if self.attn_shard:
            spec = dataclasses.replace(spec, shard=True)
        return spec

    @property
    def padded_vocab(self) -> int:
        m = max(self.pad_vocab_to, 1)
        return -(-self.vocab // m) * m

    @property
    def padded_heads(self) -> int:
        """Query-head count after TP padding (== num_heads when disabled)."""
        t = self.pad_attn_heads_to
        if t <= 0 or self.num_heads % t == 0:
            return self.num_heads
        return -(-self.num_heads // t) * t

    @property
    def kv_slots(self) -> int:
        """KV slot count used by full-sequence attention (expanded for TP)."""
        t = self.pad_attn_heads_to
        if t <= 0 or (self.num_heads % t == 0 and self.kv_heads % min(t, self.num_heads) == 0):
            return self.kv_heads
        return min(t, self.padded_heads)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adt(self):
        return jnp.dtype(self.activ_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
