"""qwen3-1.7b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "qwen3-1.7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
