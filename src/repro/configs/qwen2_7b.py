"""qwen2-7b — GQA with QKV bias [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "qwen2-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
