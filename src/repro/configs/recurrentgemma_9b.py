"""recurrentgemma-9b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000.
Block pattern (rglru, rglru, local). MRA applies to the local-attention
layers (cfg.attention.kind="mra2" routes them through the paper's scheme).
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "recurrentgemma-9b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="recurrentgemma",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    lru_width=4096,
    act="gelu",
    attention=AttentionSpec(kind="local", local_window=2048),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, local_window=32, lru_width=64, remat="none", scan_layers=False,
        attention=AttentionSpec(kind="local", local_window=32),
    )
