"""Architecture registry: the 10 assigned configs + the paper's RoBERTa models."""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, MoESpec, ShapeCfg

_ARCH_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2-7b": "qwen2_7b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "yi-6b": "yi_6b",
    "rwkv6-7b": "rwkv6_7b",
    "hubert-xlarge": "hubert_xlarge",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
}

ARCHS = tuple(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise ValueError(f"unknown arch {name!r}; known: {list(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).CONFIG
    return cfg.replace(**overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).smoke()
    return cfg.replace(**overrides) if overrides else cfg


def shape_skips(arch: str, shape: str) -> str | None:
    """Return a skip reason for (arch, shape) cells that are not well-defined."""
    cfg = get_config(arch)
    if cfg.family == "hubert" and shape in ("decode_32k", "long_500k"):
        return "encoder-only: no decode step (DESIGN.md §5)"
    return None
