"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "llama3.2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=128,
    rope_theta=5e5,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
