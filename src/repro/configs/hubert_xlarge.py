"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447; unverified].

48L d_model=1280 16H (kv=16, i.e. full MHA) d_ff=5120 vocab=504 (masked-unit
prediction codebook). Modality frontend is a STUB: input_specs() provides
precomputed 512-d frame embeddings. Encoder-only => decode shapes skipped.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "hubert-xlarge"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hubert",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    causal=False,
    norm="layernorm",
    act="gelu",
    pos="learned",
    max_seq=32768,
    frontend="audio_frames",
    frontend_dim=512,
    tie_embeddings=True,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=4, head_dim=16,
        d_ff=128, vocab=64, max_seq=512, frontend_dim=32,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2),
        remat="none",
        scan_layers=False,
    )
