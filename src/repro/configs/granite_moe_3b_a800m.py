"""granite-moe-3b-a800m — IBM Granite MoE [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512(per expert) vocab=49155,
MoE 40 experts top-8. 40 experts do NOT divide a 16-way model axis — the
sharding engine falls back to per-expert d_ff TP (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, MoESpec
from repro.core.attention import AttentionSpec

ARCH_ID = "granite-moe-3b-a800m"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoESpec(num_experts=40, top_k=8, d_ff_expert=512),
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=32, vocab=512,
        moe=MoESpec(num_experts=5, top_k=2, d_ff_expert=32, capacity_factor=2.0),
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
