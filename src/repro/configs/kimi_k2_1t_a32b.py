"""kimi-k2-1t-a32b — Kimi K2 trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(per expert) vocab=163840,
MoE 384 experts top-8.
"""
from repro.configs.base import ModelConfig, MoESpec
from repro.core.attention import AttentionSpec

ARCH_ID = "kimi-k2-1t-a32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,  # 7168 / 64
    moe=MoESpec(num_experts=384, top_k=8, d_ff_expert=2048),
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=32, vocab=512,
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=32, capacity_factor=2.0),
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
