"""internvl2-1b — InternViT + qwen2-0.5b-style LLM [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The vision frontend
is a STUB per the assignment: input_specs() provides precomputed 1024-d
patch embeddings (InternViT output), projected and prepended to the text.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "internvl2-1b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="internvl",
    num_layers=24,
    d_model=896,
    num_heads=14,
    kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    frontend="vision_patches",
    frontend_dim=1024,
    num_patches=256,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, frontend_dim=32, num_patches=8,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
