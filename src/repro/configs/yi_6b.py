"""yi-6b — llama-arch GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "yi-6b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    kv_heads=4,
    d_ff=11008,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    attention=AttentionSpec(kind="mra2", block_size=128, blocks_per_row=4,
                            decode_blocks=16),
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attention=AttentionSpec(kind="mra2", block_size=16, blocks_per_row=2,
                                decode_blocks=2),
        remat="none",
        scan_layers=False,
    )
