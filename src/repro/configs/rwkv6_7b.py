"""rwkv6-7b — Finch, data-dependent decay, attention-free [arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536. MRA inapplicable (no attention
matrix) — implemented without the technique per DESIGN.md §5.
"""
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec

ARCH_ID = "rwkv6-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,   # 4096 / rwkv_head_dim(64)
    kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_chunk=16,
    attention=AttentionSpec(kind="full"),  # unused; family is attention-free
    remat="full",
    scan_layers=True,
)


def smoke():
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=4, d_ff=128, vocab=512,
        rwkv_head_dim=16, rwkv_chunk=8, decay_lora=8, remat="none", scan_layers=False,
    )
