"""Training loop: pjit'd step, microbatch accumulation, fault tolerance.

Production behaviors implemented here (DESIGN.md §2, §4):
  * gradient accumulation over microbatches via ``lax.scan`` (memory-bounded
    global batches; optional bf16+error-feedback compressed accumulators);
  * configurable remat policy (cfg.remat), AdamW + cosine schedule,
    global-norm clipping;
  * checkpoint/restart (atomic, async) every N steps + on SIGTERM/SIGINT
    (preemption handling); restarts resume bit-identically (deterministic
    data streams keyed by step);
  * straggler mitigation: per-step wall-time EWMA with slow-step logging —
    on real multi-host deployments this feeds the same hook used here to
    flag and (via the elastic restore path) evict slow hosts;
  * elastic scaling: restore re-shards onto whatever mesh the relaunch has.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig, ShapeCfg
from repro.data import DataLoader
from repro.distributed import mesh_utils
from repro.distributed.sharding import ShardingRules, logical_to_pspec
from repro.models import get_model, init_params, param_shardings
from repro.models.params import param_pspecs
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import zero_pspec


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    grad_compression: str = "none"  # none | bf16_ef
    log_every: int = 10
    straggler_factor: float = 2.0  # steps slower than EWMA*factor are flagged
    # Kernel-path training: None = keep the model config's routing; True/False
    # force the fused Pallas fwd+bwd attention kernels on/off for this run.
    # kernel_interpret runs them in interpret mode (CPU smoke of the TPU path).
    use_kernel: Optional[bool] = None
    kernel_interpret: bool = False
    # Mesh-sharded training: (data, model) mesh built over the local devices
    # when ``train()`` is not handed a mesh explicitly. None = single device.
    # shard_attention: None = keep the model config's attn_shard; True/False
    # force the shard_map attention path on/off for this run (DESIGN.md §8).
    mesh_shape: Optional[Tuple[int, int]] = None
    shard_attention: Optional[bool] = None


def _apply_kernel_flags(cfg: ModelConfig, tc: TrainConfig) -> ModelConfig:
    if tc.use_kernel is not None:
        cfg = cfg.replace(
            attn_use_kernel=tc.use_kernel, attn_interpret=tc.kernel_interpret
        )
    if tc.shard_attention is not None:
        cfg = cfg.replace(attn_shard=tc.shard_attention)
    return cfg


def make_train_step(cfg: ModelConfig, tc: TrainConfig, optimizer: AdamW,
                    lr_fn: Callable):
    """Build the (jit-able) train_step(params, opt_state, batch) function."""
    cfg = _apply_kernel_flags(cfg, tc)
    model = get_model(cfg)

    def microbatch_grads(params, batch):
        def one(mb):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, cfg, mb), has_aux=True
            )(params)
            return grads, metrics

        if tc.microbatches == 1:
            return one(batch)
        # split leading batch dim into microbatches and scan-accumulate
        def reshape(x):
            return x.reshape((tc.microbatches, x.shape[0] // tc.microbatches) + x.shape[1:])

        mbs = jax.tree.map(reshape, batch)
        acc_dtype = jnp.bfloat16 if tc.grad_compression == "bf16_ef" else jnp.float32

        def body(carry, mb):
            acc, res, met_acc = carry
            grads, metrics = one(mb)
            if tc.grad_compression == "bf16_ef":
                from repro.optim.compression import EFState, compress

                gq, ef = compress(grads, EFState(res))
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), acc, gq
                )
                res = ef.residual
            else:
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            met_acc = jax.tree.map(lambda a, m: a + m, met_acc, metrics)
            return (acc, res, met_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        res0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        met0 = {"loss": jnp.zeros((), jnp.float32), "aux_loss": jnp.zeros((), jnp.float32),
                "nll": jnp.zeros((), jnp.float32)}
        (acc, _, met), _ = jax.lax.scan(body, (zeros, res0, met0), mbs)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / tc.microbatches, acc)
        met = jax.tree.map(lambda m: m / tc.microbatches, met)
        return grads, met

    def train_step(params, opt_state, batch):
        grads, metrics = microbatch_grads(params, batch)
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def _batch_shardings(batch, mesh, rules=None):
    from jax.sharding import NamedSharding

    def one(x):
        spec = logical_to_pspec(x.shape, ("batch",) + (None,) * (x.ndim - 1), mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch)


def train(cfg: ModelConfig, shape: ShapeCfg, tc: TrainConfig, *, mesh=None,
          rules: Optional[ShardingRules] = None, on_metrics=None):
    """Full driver: init/restore -> loop -> checkpoint. Returns final metrics."""
    cfg = _apply_kernel_flags(cfg, tc)
    if mesh is None and tc.mesh_shape is not None:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(*tc.mesh_shape)
    model = get_model(cfg)
    optimizer = AdamW()
    lr_fn = cosine_schedule(tc.lr, tc.warmup, tc.steps)
    step_fn = make_train_step(cfg, tc, optimizer, lr_fn)

    specs = model.param_specs(cfg)
    if mesh is not None:
        shardings = param_shardings(specs, mesh, rules)
        base_specs = param_pspecs(specs, mesh, rules)
        opt_shardings = jax.tree.map(
            lambda s, b: jax.sharding.NamedSharding(
                mesh, zero_pspec(s.shape, mesh, rules, base=b)),
            specs, base_specs, is_leaf=lambda s: hasattr(s, "axes"),
        )
    params = init_params(specs, jax.random.PRNGKey(tc.seed))
    if mesh is not None:
        params = jax.tree.map(jax.device_put, params, shardings)
    opt_state = optimizer.init(params)
    if mesh is not None:
        # ZeRO-1: moments shard over the data axes on top of the parameter's
        # own TP/EP spec (optim/adamw.zero_pspec); step stays replicated.
        opt_state = opt_state._replace(
            mu=jax.tree.map(jax.device_put, opt_state.mu, opt_shardings),
            nu=jax.tree.map(jax.device_put, opt_state.nu, opt_shardings),
        )

    start_step = 0
    ckpter = AsyncCheckpointer()
    if tc.ckpt_dir:
        last = latest_step(tc.ckpt_dir)
        if last is not None:
            params = restore(tc.ckpt_dir, last, params,
                             shardings=shardings if mesh is not None else None)
            opt_sh = None
            if mesh is not None:
                # resume keeps the ZeRO-1 moment placement of the fresh path
                opt_sh = opt_state._replace(
                    step=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()),
                    mu=opt_shardings, nu=opt_shardings,
                )
            opt_state = restore(
                tc.ckpt_dir + "/opt", last, opt_state,
                shardings=opt_sh,
            )
            start_step = last

    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # preemption: checkpoint on SIGTERM/SIGINT then exit cleanly
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True

    old_term = signal.signal(signal.SIGTERM, _handler)

    loader = DataLoader(cfg, shape, seed=tc.seed, start_step=start_step)
    ewma = None
    metrics_out = {}
    try:
        for step in range(start_step, tc.steps):
            _, batch = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if mesh is not None:
                batch = jax.tree.map(jax.device_put, batch, _batch_shardings(batch, mesh, rules))
            t0 = time.perf_counter()
            with mesh_utils.use_mesh(mesh):
                params, opt_state, metrics = jitted(params, opt_state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > tc.straggler_factor * ewma and step > start_step + 3:
                print(f"[straggler] step {step} took {dt:.3f}s (ewma {ewma:.3f}s)")
            metrics["step_time_s"] = dt
            metrics_out = metrics
            if on_metrics:
                on_metrics(step, metrics)
            if step % tc.log_every == 0:
                print(f"step {step}: loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if tc.ckpt_dir and ((step + 1) % tc.ckpt_every == 0 or preempted["flag"]):
                ckpter.save(tc.ckpt_dir, step + 1, params)
                ckpter.wait()
                from repro.checkpoint import save as sync_save

                sync_save(tc.ckpt_dir + "/opt", step + 1, opt_state)
            if preempted["flag"]:
                print(f"[preempt] checkpointed at step {step + 1}; exiting")
                break
    finally:
        loader.close()
        ckpter.wait()
        signal.signal(signal.SIGTERM, old_term)
    return params, opt_state, metrics_out
