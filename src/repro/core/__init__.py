"""Core: the paper's contribution — MRA-2 approximate self-attention."""
from .attention import AttentionSpec, decode_attention, self_attention
from .mra import MraConfig, block_mean, full_attention, mra2_attention
from .mra_decode import PyramidState, full_decode_attention, mra2_decode_attention

__all__ = [
    "AttentionSpec",
    "MraConfig",
    "PyramidState",
    "block_mean",
    "decode_attention",
    "full_attention",
    "full_decode_attention",
    "mra2_attention",
    "mra2_decode_attention",
    "self_attention",
]
