"""Attention dispatch: one API, every mechanism in the paper's comparison.

Models declare an ``AttentionSpec``; ``self_attention`` routes to MRA-2 /
MRA-2-s / exact softmax / a baseline. This is the integration point that
makes the paper's technique a first-class, drop-in feature (paper §6:
"our implementation can be directly plugged into existing Transformers").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import baselines
from .mra import MraConfig, NEG_INF, full_attention, mra2_attention
from .mra_decode import (
    full_chunk_attention,
    full_decode_attention,
    mra2_chunk_attention,
    mra2_decode_attention,
)


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Which attention mechanism a model layer uses.

    kind: "full" | "mra2" | "mra2_s" | "local" | any baselines.REGISTRY key.
    block_size / blocks_per_row: MRA-2 parameters (paper defaults 32 / 4-16).
    decode_blocks: MRA decode-time budget (exact KV blocks per new token).
    coarse_only: MRA draft mode (DESIGN.md §10) — no top-m high-resolution
      selection beyond the mandatory own/diagonal block: a query attends its
      own block exactly and every other live block through the pyramid block
      sums alone. This is the coarse level of the multiresolution
      decomposition used as a free draft model by speculative decoding
      (serve/speculative.py); O(S/b) per decoded token. Implemented as
      blocks_per_row = 1 (full-sequence path: the force-selected diagonal is
      the entire budget) and decode_blocks = 1 (decode/chunk path: the
      force-selected own block is the entire budget).
    local_window: window for kind=="local" (RecurrentGemma local attention).
    use_kernel: route the fused Pallas kernels — training/prefill through
      kernels/block_sparse_attn.py (fwd + bwd, DESIGN.md §3), decode and
      chunked prefill through the serving kernel kernels/chunk_attn.py
      (fwd-only fused two-level softmax, DESIGN.md §11).
    shard: run attention inside a shard_map over the active mesh (batch ->
      data axes, kv-heads -> model axis); falls back to the bit-identical
      local path when no mesh is active or shapes don't divide
      (distributed/shard_attn.py, DESIGN.md §8).
    """

    kind: str = "full"
    block_size: int = 32
    blocks_per_row: int = 4
    decode_blocks: int = 16
    coarse_only: bool = False
    local_window: int = 1024
    softmax_scale: Optional[float] = None
    use_kernel: bool = False
    kernel_bwd: str = "pallas"  # bwd impl on the kernel path: pallas | jnp
    # serving-kernel dispatch mode (DESIGN.md §11): "latency" | "throughput"
    # | "auto" (decode waves -> latency, prefill/verify chunks -> throughput)
    kernel_mode: str = "auto"
    interpret: bool = False
    shard: bool = False
    # beyond-paper (§Perf Y3): int8 KV cache with per-token-per-head scales —
    # halves decode memory footprint and HBM traffic; MRA decode dequantizes
    # only the gathered blocks. Only honored by the mra2/mra2_s decode path.
    kv_quant: bool = False
    # H-level pyramid (DESIGN.md §14): levels=2 is the paper's two-level
    # MRA-2 (bit-identical to the pre-hierarchy engine); levels>=3 adds
    # collapsed rings over evicted history (core/hier.py) so the ring cache
    # serves contexts far beyond its fine window. hier_pages sizes each
    # collapsed level's ring (0 = same as the fine page count).
    levels: int = 2
    hier_pages: int = 0
    # Background resolution of coarse-only speculative drafts (MraConfig.
    # draft_level): >1 folds the far field over 2^(draft_level-1)-page
    # groups. jnp-route only; draft_config() keeps drafts off the kernel.
    draft_level: int = 1

    @property
    def budget_blocks(self) -> int:
        """Decode-time selection budget (1 when coarse-only: own block)."""
        return 1 if self.coarse_only else self.decode_blocks

    def mra_config(self, causal: bool) -> MraConfig:
        return MraConfig(
            block_size=self.block_size,
            blocks_per_row=1 if self.coarse_only else self.blocks_per_row,
            variant="sparse" if self.kind == "mra2_s" else "full",
            causal=causal,
            softmax_scale=self.softmax_scale,
            use_kernel=self.use_kernel,
            kernel_bwd=self.kernel_bwd,
            kernel_mode=self.kernel_mode,
            interpret=self.interpret,
            draft_level=self.draft_level,
        )

    def replace(self, **kw) -> "AttentionSpec":
        return dataclasses.replace(self, **kw)


def self_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    spec: AttentionSpec,
    *,
    causal: bool = False,
    key_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Sequence self-attention (training / prefill). q (B,Hq,N,D), k/v (B,Hkv,N,D)."""
    if spec.shard:
        from repro.distributed.shard_attn import sharded_self_attention

        out = sharded_self_attention(q, k, v, spec, causal=causal,
                                     key_mask=key_mask)
        if out is not None:
            return out
    if spec.kind in ("mra2", "mra2_s"):
        return mra2_attention(q, k, v, spec.mra_config(causal), key_mask=key_mask)
    if spec.kind == "full":
        return full_attention(
            q, k, v, causal=causal, softmax_scale=spec.softmax_scale, key_mask=key_mask
        )
    if spec.kind == "local":
        return _local_attention(q, k, v, spec, causal=causal, key_mask=key_mask)
    fn = baselines.REGISTRY.get(spec.kind)
    if fn is None:
        raise ValueError(f"unknown attention kind {spec.kind!r}")
    # baselines are bidirectional approximators (paper protocol); GQA handled
    # by expanding KV heads (baselines are never used on the production path).
    G = q.shape[1] // k.shape[1]
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)
    return fn(q, k, v, softmax_scale=spec.softmax_scale)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    spec: AttentionSpec,
    *,
    pyramid=None,
    page_blocks=None,
    k_scale=None,
    v_scale=None,
) -> jax.Array:
    """Single-token decode attention against a KV cache."""
    if spec.shard:
        from repro.distributed.shard_attn import sharded_decode_attention

        out = sharded_decode_attention(
            q, k_cache, v_cache, lengths, spec, pyramid=pyramid,
            page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale,
        )
        if out is not None:
            return out
    if spec.kind in ("mra2", "mra2_s"):
        cfg = spec.mra_config(causal=True)
        return mra2_decode_attention(
            q, k_cache, v_cache, lengths, cfg,
            decode_blocks=spec.budget_blocks, pyramid=pyramid,
            page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale,
        )
    if spec.kind == "local":
        return _local_decode_attention(q, k_cache, v_cache, lengths, spec)
    return full_decode_attention(q, k_cache, v_cache, lengths,
                                 softmax_scale=spec.softmax_scale)


def chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_pos: jax.Array,
    spec: AttentionSpec,
    *,
    pyramid=None,
    page_blocks=None,
    k_scale=None,
    v_scale=None,
) -> jax.Array:
    """Chunked-prefill attention: C queries (already written to the cache)
    attend the KV cache causally at their global positions ``q_pos`` (B, C).
    This is what lets the serving engine prefill prompts in O(P/C) jitted
    dispatches instead of O(P) single-token decode replays (DESIGN.md §9).
    """
    if spec.shard:
        from repro.distributed.shard_attn import sharded_chunk_attention

        out = sharded_chunk_attention(
            q, k_cache, v_cache, lengths, q_pos, spec, pyramid=pyramid,
            page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale,
        )
        if out is not None:
            return out
    if spec.kind in ("mra2", "mra2_s"):
        cfg = spec.mra_config(causal=True)
        return mra2_chunk_attention(
            q, k_cache, v_cache, lengths, q_pos, cfg,
            decode_blocks=spec.budget_blocks, pyramid=pyramid,
            page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale,
        )
    window = spec.local_window if spec.kind == "local" else None
    return full_chunk_attention(q, k_cache, v_cache, lengths, q_pos,
                                softmax_scale=spec.softmax_scale,
                                local_window=window)


def _local_attention(q, k, v, spec, *, causal, key_mask):
    """Sliding-window attention (RecurrentGemma's local layers).

    Uses banded block attention: each query block sees its own and the
    previous ``w//bs`` key blocks. O(n * w).
    """
    B, Hq, N, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    w = spec.local_window
    bs = min(w, N)
    if N % bs != 0:
        pad = (-N) % bs
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n = q.shape[2]
    nb = n // bs
    scale = spec.softmax_scale if spec.softmax_scale is not None else 1.0 / (D**0.5)
    qb = q.reshape(B, Hkv, G, nb, bs, D).astype(jnp.float32)
    kb = k.reshape(B, Hkv, nb, bs, D).astype(jnp.float32)
    vb = v.reshape(B, Hkv, nb, bs, D).astype(jnp.float32)
    if key_mask is None:
        key_mask = jnp.arange(n) < N
        key_mask = jnp.broadcast_to(key_mask[None], (B, n))
    else:
        key_mask = jnp.pad(key_mask, ((0, 0), (0, n - key_mask.shape[1])))
    mb = key_mask.reshape(B, nb, bs)

    shifts = (-1, 0) if causal else (-1, 0, 1)
    scores, vals, valid = [], [], []
    for sh in shifts:
        kk = jnp.roll(kb, -sh, axis=2)
        vv = jnp.roll(vb, -sh, axis=2)
        mm = jnp.roll(mb, -sh, axis=1)
        ok_blk = (jnp.arange(nb) + sh >= 0) & (jnp.arange(nb) + sh < nb)
        s = jnp.einsum("bhgnid,bhnjd->bhgnij", qb, kk) * scale
        qi = jnp.arange(bs)[:, None]
        kj = jnp.arange(bs)[None, :] + sh * bs
        if causal:
            dist_ok = (kj <= qi) & (qi - kj < w)
        else:
            dist_ok = jnp.abs(qi - kj) <= w // 2
        mask = dist_ok[None, None, None, None] & ok_blk[None, None, None, :, None, None]
        mask = mask & mm[:, None, None, :, None, :]
        s = jnp.where(mask, s, NEG_INF)
        scores.append(s)
        vals.append(vv)
    s_all = jnp.concatenate(scores, axis=-1)
    v_all = jnp.concatenate(vals, axis=-2)
    p = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bhgnij,bhnjd->bhgnid", p, v_all)
    return out.reshape(B, Hq, n, D)[:, :, :N].astype(q.dtype)


def _local_decode_attention(q, k_cache, v_cache, lengths, spec):
    """Decode attention restricted to the last ``local_window`` positions."""
    B, Hq, _, D = q.shape
    S = k_cache.shape[2]
    pos = jnp.arange(S)[None, :]
    ok = (pos < lengths[:, None]) & (pos >= lengths[:, None] - spec.local_window)
    scale = spec.softmax_scale if spec.softmax_scale is not None else 1.0 / (D**0.5)
    Hkv = k_cache.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhjd->bhgj", qg, k_cache.astype(jnp.float32)) * scale
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgj,bhjd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)
