"""MRA-2 attention for autoregressive decode (one query vs. a long KV cache).

The paper evaluates bidirectional encoders; this module is the beyond-paper
adaptation of the same two-level scheme to decoding (DESIGN.md §7): the KV
cache is viewed as ``nb = S/b`` key blocks, coarse scores
``mu_y = exp(q (K~_b)_y^T * scale)`` pick the top-``m`` blocks for *exact*
attention, all remaining valid blocks contribute the coarse background
(``variant="full"``) exactly as in the prefill formulation with a 1-row query
block. Complexity per decoded token: O(S/b + m*b) instead of O(S) — this is
what makes the ``long_500k`` shapes sub-quadratic end-to-end.

An incrementally-maintained block-sum pyramid (``PyramidState``) makes the
coarse scores O(1) to update per appended token instead of O(S) to recompute.

Ring-paged cache (DESIGN.md §9): the physical cache of ``nb`` block-sized
pages can serve a *logical* stream longer than the cache. ``page_blocks``
(B, nb) int32 maps physical page -> logical block index (-1 = never
written); position ``p`` lives at physical index ``p % S`` and its block at
page ``(p // b) % nb``, so appending evicts the oldest background block in
ring order while the pyramid entry *is* the page-table row. All attention
entry points below accept ``page_blocks``; ``None`` means the identity table
(page y holds block y), which reproduces the dense layout bit-for-bit.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .mra import MraConfig, NEG_INF, FORCE_BONUS


class PyramidState(NamedTuple):
    """Incremental block-sum pyramid over the KV cache.

    k_sum / v_sum: (B, Hkv, nb, D) running sums of keys/values per block.
    The block mean is ``sum / count`` with ``count`` derived from ``length``.
    upper: optional ``core.hier.HierUpper`` — the collapsed-level + tail
    view of *evicted* history in an H-level hierarchy (DESIGN.md §14).
    ``None`` (the default, and always at levels=2) keeps every attention
    path byte-identical to the two-level scheme.
    """

    k_sum: jax.Array
    v_sum: jax.Array
    upper: Optional[NamedTuple] = None

    @staticmethod
    def init(batch: int, kv_heads: int, nb: int, d: int, dtype=jnp.float32):
        z = jnp.zeros((batch, kv_heads, nb, d), dtype)
        return PyramidState(z, z)

    def append(self, k_new: jax.Array, v_new: jax.Array, pos: jax.Array, block: int):
        """Add one token's K/V at position ``pos`` (per-batch array (B,)).

        Dense layout only: ``pos`` must lie inside the ``nb * block`` capacity.
        Past it the target block does not exist — an unguarded scatter would be
        clamped by JAX to ``nb - 1``, silently corrupting the last block's sums
        — so out-of-capacity appends are dropped instead (no-op for that
        slot). Ring streams that outlive the capacity must go through
        ``ring_pyramid_update``, which recycles pages instead of dropping.
        """
        nb = self.k_sum.shape[2]
        blk = pos // block  # (B,)
        in_cap = (blk < nb)[:, None, None]
        b_idx = jnp.arange(self.k_sum.shape[0])
        blk = jnp.minimum(blk, nb - 1)  # clamp AFTER masking the contribution
        k_sum = self.k_sum.at[b_idx, :, blk].add(
            jnp.where(in_cap, k_new.astype(self.k_sum.dtype), 0))
        v_sum = self.v_sum.at[b_idx, :, blk].add(
            jnp.where(in_cap, v_new.astype(self.v_sum.dtype), 0))
        return PyramidState(k_sum, v_sum)


def identity_page_table(batch: int, nb: int) -> jax.Array:
    """Dense layout: physical page y holds logical block y."""
    return jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[None], (batch, nb))


def paged_block_counts(lengths: jax.Array, page_blocks: jax.Array, block: int):
    """(B, nb) valid tokens per *page* given the page table and total length.

    A live page holding logical block y contains tokens [y*b, min(len, y*b+b));
    only the newest block is ever partial (eviction replaces whole pages), so
    for the identity table this is the dense per-block count clip.
    """
    starts = page_blocks * block
    c = jnp.clip(lengths[:, None] - starts, 0, block)
    return jnp.where(page_blocks >= 0, c, 0)


def paged_position_mask(lengths: jax.Array, page_blocks: jax.Array, S: int,
                        block: int) -> jax.Array:
    """(B, S) validity of each physical cache index under the page table."""
    idx = jnp.arange(S)
    pb = jnp.take(page_blocks, idx // block, axis=1)  # (B, S)
    pos = pb * block + (idx % block)[None, :]
    return (pb >= 0) & (pos < lengths[:, None])


def ring_pyramid_update(
    pyramid: PyramidState,
    page_blocks: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    block: int,
    active: Optional[jax.Array] = None,
):
    """Append one token's K/V (B, Hkv, D) at global position ``pos`` (B,).

    Ring-paged version of ``PyramidState.append``: the target page is
    ``(pos // block) % nb``; when the token starts a new block the page is
    *recycled* — its old block sum is dropped (eviction) and ownership moves
    to the new logical block. Slots with ``active`` False are left untouched
    bit-for-bit. Returns (PyramidState, page_blocks).
    """
    nb = pyramid.k_sum.shape[2]
    b_idx = jnp.arange(pyramid.k_sum.shape[0])
    blk = pos // block
    page = blk % nb
    if active is None:
        active = jnp.ones(pos.shape, bool)
    k_old = pyramid.k_sum[b_idx, :, page]
    v_old = pyramid.v_sum[b_idx, :, page]
    # recycle the page (drop the evicted block's sums) only when an *active*
    # slot writes the first token of a new block
    keep = ~(active & ((pos % block) == 0))
    k_base = jnp.where(keep[:, None, None], k_old, 0.0)
    v_base = jnp.where(keep[:, None, None], v_old, 0.0)
    am = active[:, None, None]
    k_sum = pyramid.k_sum.at[b_idx, :, page].set(
        k_base + jnp.where(am, k_new.astype(pyramid.k_sum.dtype), 0.0))
    v_sum = pyramid.v_sum.at[b_idx, :, page].set(
        v_base + jnp.where(am, v_new.astype(pyramid.v_sum.dtype), 0.0))
    old_owner = page_blocks[b_idx, page]
    page_blocks = page_blocks.at[b_idx, page].set(
        jnp.where(active, blk.astype(page_blocks.dtype), old_owner))
    return PyramidState(k_sum, v_sum), page_blocks


def quantize_kv(x: jax.Array):
    """Per-token-per-head int8 quantization. x (B,H,*,D) -> (int8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def mra2_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    cfg: MraConfig,
    *,
    decode_blocks: int = 16,
    pyramid: Optional[PyramidState] = None,
    page_blocks: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """One-step decode attention with MRA block selection.

    Args:
      q: (B, Hq, 1, D) the new token's query.
      k_cache/v_cache: (B, Hkv, S, D), S a static multiple of cfg.block_size.
      lengths: (B,) valid prefix length (includes the token being decoded).
      cfg: MraConfig (block_size, variant, compute dtype are honored).
      decode_blocks: selection budget m (number of exact key blocks).
      pyramid: optional incremental block sums; recomputed from the cache
        when absent.
      page_blocks: (B, nb) ring page table (physical page -> logical block,
        -1 dead); None = dense identity layout (page y is block y).
      k_scale/v_scale: (B, Hkv, S) per-token dequant scales when the cache is
        int8 (§Perf Y3); gathered blocks are dequantized after the gather.

    Returns:
      (B, Hq, 1, D) attention output.
    """
    # the decode step IS chunked-prefill attention with a C == 1 chunk whose
    # query sits at the newest position — one implementation, one set of
    # stabilizer/paging/dequant semantics (tests/test_engine.py pins the
    # equivalence; the engine relies on it for its conformance contract)
    return mra2_chunk_attention(
        q, k_cache, v_cache, lengths, (lengths - 1)[:, None], cfg,
        decode_blocks=decode_blocks, pyramid=pyramid, page_blocks=page_blocks,
        k_scale=k_scale, v_scale=v_scale,
    )


def mra2_coarse_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    cfg: MraConfig,
    *,
    pyramid: Optional[PyramidState] = None,
    page_blocks: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Coarse-only decode attention: the speculative draft pass (DESIGN.md §10).

    ``mra2_decode_attention`` with the selection budget collapsed to the one
    mandatory block — the query's own (force-selected, exactly masked) block.
    Every other live page contributes only through its pyramid block sum, so
    a draft token costs O(S/b) with no O(m*b) gather at all: the pyramid
    pages the ring cache already maintains *are* the draft model. The serving
    dispatch reaches the same math through ``AttentionSpec.coarse_only``
    (budget_blocks == 1); this named form exists for direct measurement —
    the fidelity of the coarse level is what bounds speculative decoding's
    acceptance rate, and benchmarks/approx_error.py reports it next to the
    budgeted variants.
    """
    return mra2_decode_attention(
        q, k_cache, v_cache, lengths, cfg, decode_blocks=1, pyramid=pyramid,
        page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale,
    )


class ChunkPrelude(NamedTuple):
    """Shared jnp half of chunk/decode MRA attention (DESIGN.md §11).

    Only the page *statistics* — grouped queries, the page table/counts and
    the k/v page means. Coarse scoring, the causal block mask, own-block
    force selection and top-m all happen downstream: in jnp on the oracle
    route (``_select_pages``), *inside the kernel* on the Pallas route
    (``kernels/chunk_attn.py``), so no coarse-score tensor reaches HBM
    there. ``scale``/``block_size`` are static trace-time values.
    """

    qg: jax.Array        # (B, Hkv, G, C, D) grouped queries, compute dtype
    pb: jax.Array        # (B, nb) page table (identity when unpaged)
    counts: jax.Array    # (B, nb) valid tokens per page
    k_ds: jax.Array      # (B, Hkv, nb, D) per-page K means (coarse keys)
    v_ds: jax.Array      # (B, Hkv, nb, D) per-page V means
    scale: float
    block_size: int
    # H-level hierarchy (DESIGN.md §14): collapsed-level + tail means/counts
    # of evicted history (core.hier.HierUpper), folded into the background
    # softmax at their own resolution. None on every two-level path.
    upper: Optional[NamedTuple] = None


class PageSelection(NamedTuple):
    """jnp-route top-m page selection (the kernel's in-chip mirror)."""

    coarse_m: jax.Array  # (B, Hkv, G, C, nb) masked coarse scores
    y_idx: jax.Array     # (B, Hkv, G, C, m) selected physical pages
    sel_ok: jax.Array    # (B, Hkv, G, C, m) selection validity
    allowed: jax.Array   # (B, 1, 1, C, nb) valid-target support mask
    ownl: jax.Array      # (B, 1, 1, C, nb) query's own *live* block


def _chunk_prelude(q, k_cache, v_cache, lengths, q_pos, cfg, decode_blocks,
                   pyramid, page_blocks) -> ChunkPrelude:
    """Page stats shared by the jnp and Pallas routes."""
    B, Hq, C, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    b = cfg.block_size
    if Hq % Hkv != 0:
        raise ValueError(
            f"query heads {Hq} (q {q.shape}) are not a multiple of KV heads "
            f"{Hkv} (k_cache {k_cache.shape}); GQA grouping is impossible")
    if S % b != 0:
        raise ValueError(
            f"KV cache length {S} (k_cache {k_cache.shape}) is not a "
            f"multiple of block_size {b}; the cache cannot be paged into "
            f"whole pyramid blocks")
    G = Hq // Hkv
    nb = S // b
    scale = cfg.softmax_scale if cfg.softmax_scale is not None else 1.0 / (D**0.5)
    cdt = cfg.compute_dtype

    pb = page_blocks if page_blocks is not None else identity_page_table(B, nb)
    if pb.shape != (B, nb):
        raise ValueError(
            f"page_blocks shape {pb.shape} does not match (B, nb) = "
            f"({B}, {nb}) for k_cache {k_cache.shape}, block_size {b}")
    counts = paged_block_counts(lengths, pb, b).astype(cdt)  # (B, nb)
    if pyramid is None:
        mask = paged_position_mask(lengths, pb, S, b).astype(k_cache.dtype)
        k_sum = jnp.sum(
            (k_cache * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D),
            axis=3, dtype=cdt)
        v_sum = jnp.sum(
            (v_cache * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D),
            axis=3, dtype=cdt)
    else:
        k_sum, v_sum = pyramid.k_sum.astype(cdt), pyramid.v_sum.astype(cdt)
    denom = jnp.maximum(counts, 1.0)[:, None, :, None]
    k_ds = k_sum / denom  # (B, Hkv, nb, D)
    v_ds = v_sum / denom

    qg = q.reshape(B, Hkv, G, C, D).astype(cdt)
    upper = pyramid.upper if pyramid is not None else None
    return ChunkPrelude(qg, pb, counts, k_ds, v_ds, scale, b, upper)


def _select_pages(pre: ChunkPrelude, q_pos, m: int) -> PageSelection:
    """Coarse scores, causal block mask, and top-m selection (jnp oracle).

    The Pallas route mirrors this math on-chip (kernels/chunk_attn.py); the
    two must select identical page sets, so any change here is a kernel
    contract change (tests/test_chunk_kernel.py pins the equivalence).

    Selection validity is carried as an explicit mask: a page is a valid
    exact-attention target iff it is live and causally allowed (the query's
    own block, when live, is always allowed). A *dead* own block — a fresh
    slot whose query block holds zero live tokens — is neither
    force-selected nor valid, so such rows produce exact zeros instead of
    attending stale cache garbage. (The old sentinel ``top_vals >
    NEG_INF * 0.5`` let the FORCE_BONUS of a dead own block pass the
    threshold; the mask-derived ``sel_ok`` cannot.)

    H-level walk (DESIGN.md §14): selection IS the coarse->fine refinement
    of the hierarchy, organised by residency rather than recursion. Context
    outside the fine window lives only at the collapsed levels
    (``pre.upper``) and folds into the softmax at its own resolution — the
    coarser the level, the older and more compressed the span — while this
    function walks the finest resident level: every in-window page is
    scored through its level-1 mean (the coarse read), the top-m subtrees
    refine to exact token attention (the fine read), and the rest fold
    through the same level-1 means as background. Each query therefore
    refines only its top-scoring subtrees; distant context is summarised at
    the coarsest resolution that still holds it. Per-query descent *within*
    the window (score level 2 first, open only promising level-2 entries
    into their level-1 children) is a future refinement — it changes this
    kernel contract, so it rides the same pinned-parity process as any
    selection change.
    """
    b = pre.block_size
    live = pre.counts > 0  # (B, nb)
    jq = q_pos // b  # (B, C) query block index
    pb_q = pre.pb[:, None, None, None, :]  # (B,1,1,1,nb)
    jq_q = jq[:, None, None, :, None]  # (B,1,1,C,1)
    # causal at block granularity: past blocks are background candidates, the
    # query's own live block is force-selected (exactly masked), future
    # excluded. allowed == the valid-selection mask (own ∧ live ⊆ allowed).
    allowed = live[:, None, None, None, :] & (pb_q <= jq_q)
    ownl = (pb_q == jq_q) & (pb_q >= 0) & live[:, None, None, None, :]
    coarse = jnp.einsum("bhgcd,bhyd->bhgcy", pre.qg, pre.k_ds) * pre.scale
    coarse_m = jnp.where(allowed, coarse, NEG_INF)  # (B,Hkv,G,C,nb)
    sel_scores = coarse_m + FORCE_BONUS * ownl
    _, y_idx = jax.lax.top_k(sel_scores, m)  # (B, Hkv, G, C, m)
    sel_ok = jnp.take_along_axis(
        jnp.broadcast_to(allowed, sel_scores.shape), y_idx, axis=-1)
    return PageSelection(coarse_m, y_idx, sel_ok, allowed, ownl)


def mra2_chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_pos: jax.Array,
    cfg: MraConfig,
    *,
    decode_blocks: int = 16,
    pyramid: Optional[PyramidState] = None,
    page_blocks: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Chunked-prefill attention: C queries vs. the (ring-paged) KV cache.

    The chunked generalization of ``mra2_decode_attention``: per query token
    at global position ``p`` the coarse page scores pick the top-``m`` live
    pages among blocks strictly before ``p // b`` for exact attention, the
    query's own (partial) block is force-selected and masked exactly to
    ``pos_k <= p``, and the remaining live past pages contribute the coarse
    background. With C == 1 and ``q_pos == lengths - 1`` this is numerically
    identical to the decode path (tests/test_engine.py pins it).

    With ``cfg.use_kernel`` only the page-stats prelude stays here: coarse
    scoring, top-m selection and the gather/two-level-softmax/background/
    normalize tail all run inside the fused Pallas serving kernel
    (``kernels/chunk_attn.py``, DESIGN.md §11) in one of two MXU-shaped
    modes — ``cfg.kernel_mode`` "latency" (single-query tiles) or
    "throughput" (multi-query tiles), with "auto" resolving at trace time
    from C. Forward-only — the serving path is never differentiated. This
    jnp route is the differential oracle the kernel is pinned against.

    Args:
      q: (B, Hq, C, D) chunk queries; their K/V must already be in the cache.
      lengths: (B,) total written length (chunk included).
      q_pos: (B, C) global position of each query token.
      page_blocks: (B, nb) ring page table; None = dense identity layout.

    Returns:
      (B, Hq, C, D) attention output.
    """
    B, Hq, C, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    b = cfg.block_size
    nb = S // b
    cdt = cfg.compute_dtype
    if q_pos.shape != (B, C):
        raise ValueError(
            f"q_pos shape {q_pos.shape} does not match (B, C) = ({B}, {C}) "
            f"of q {q.shape}")

    pre = _chunk_prelude(q, k_cache, v_cache, lengths, q_pos, cfg,
                         decode_blocks, pyramid, page_blocks)
    m = min(decode_blocks, nb)
    if cfg.use_kernel:
        from repro.kernels.chunk_attn import chunk_attention_kernel

        out = chunk_attention_kernel(
            pre, k_cache, v_cache, q_pos, m=m, k_scale=k_scale,
            v_scale=v_scale, include_bg=cfg.variant == "full",
            interpret=cfg.interpret, mode=cfg.kernel_mode)
        return out.astype(q.dtype)

    qg, pb, counts = pre.qg, pre.pb, pre.counts
    v_ds, scale = pre.v_ds, pre.scale
    sel = _select_pages(pre, q_pos, m)
    coarse_m, y_idx, sel_ok = sel.coarse_m, sel.y_idx, sel.sel_ok
    allowed, own = sel.allowed, sel.ownl

    c = jnp.maximum(jnp.max(coarse_m, axis=-1), NEG_INF * 0.5)  # (B,Hkv,G,C)
    up = pre.upper
    if up is not None and cfg.variant == "full":
        # Collapsed levels + tail (DESIGN.md §14): score the per-entry means.
        # Entries hold only *evicted* (strictly past) tokens, so there is no
        # causal mask — liveness (count > 0) is the only gate. Their maxima
        # join the row stabilizer: collapsed history can dominate the live
        # window, and the background exp must not overflow.
        hlive = (up.counts > 0)[:, None, None, None, :]  # (B,1,1,1,NU)
        hmu = jnp.einsum(
            "bhgcd,bhyd->bhgcy", qg, up.k_mean.astype(cdt)) * scale
        hmu = jnp.where(hlive, hmu, NEG_INF)
        c = jnp.maximum(c, jnp.max(hmu, axis=-1))

    # ---- exact term over selected pages ------------------------------------
    k_blocks = k_cache.reshape(B, Hkv, nb, b, D)[:, :, None, None]
    v_blocks = v_cache.reshape(B, Hkv, nb, b, D)[:, :, None, None]
    gidx = jnp.broadcast_to(y_idx[..., None, None], y_idx.shape + (1, 1))
    k_sel = jnp.take_along_axis(k_blocks, gidx, axis=4).astype(cdt)
    v_sel = jnp.take_along_axis(v_blocks, gidx, axis=4).astype(cdt)
    if k_scale is not None:  # int8 cache: dequantize the gathered blocks only
        gs = jnp.broadcast_to(y_idx[..., None], y_idx.shape + (1,))
        ks = jnp.take_along_axis(
            k_scale.reshape(B, Hkv, nb, b)[:, :, None, None], gs, axis=4
        ).astype(cdt)
        vs = jnp.take_along_axis(
            v_scale.reshape(B, Hkv, nb, b)[:, :, None, None], gs, axis=4
        ).astype(cdt)
        k_sel = k_sel * ks[..., None]
        v_sel = v_sel * vs[..., None]

    s = jnp.einsum("bhgcd,bhgcmjd->bhgcmj", qg, k_sel) * scale
    blk_sel = jnp.take_along_axis(
        jnp.broadcast_to(pb[:, None, None, None, :], (B, Hkv, G, C, nb)),
        y_idx, axis=-1)
    pos = blk_sel[..., None] * b + jnp.arange(b)  # (B,Hkv,G,C,m,b)
    ok = (pos >= 0) & (pos <= q_pos[:, None, None, :, None, None])
    ok = ok & sel_ok[..., None]
    fine_max = jnp.max(jnp.where(ok, s, NEG_INF), axis=(-1, -2))
    c_tok = jax.lax.stop_gradient(jnp.maximum(c, fine_max))  # (B,Hkv,G,C)
    adj = jnp.exp(c - c_tok)
    a = jnp.where(ok, jnp.exp(jnp.minimum(s - c_tok[..., None, None], 80.0)), 0.0)
    out = jnp.einsum("bhgcmj,bhgcmjd->bhgcd", a, v_sel)
    rs = jnp.sum(a, axis=(-1, -2))  # (B,Hkv,G,C)

    # ---- coarse background ---------------------------------------------------
    if cfg.variant == "full":
        sel_grid = jnp.any(
            (y_idx[..., None] == jnp.arange(nb)) & sel_ok[..., None], axis=-2
        )  # (B,Hkv,G,C,nb)
        bg = allowed & ~own & ~sel_grid
        if cfg.draft_level > 1:
            # Coarser far field (DESIGN.md §14): fold the background over
            # groups of 2^(draft_level-1) physically adjacent ring pages. A
            # group is aggregated only when *every* member is a background
            # page (all live, causal, unselected) — the group mean is then a
            # count-weighted convex combination of member means, so its
            # score never exceeds the row stabilizer. Mixed groups (own /
            # selected / partial pages near the ring head) fall back to the
            # per-page background below.
            gsz = 1 << (cfg.draft_level - 1)
            if nb % gsz:
                raise ValueError(
                    f"draft_level={cfg.draft_level} aggregates the "
                    f"background over {gsz}-page groups, but nb={nb} pages "
                    f"do not divide evenly")
            ng = nb // gsz
            grp = bg.reshape(*bg.shape[:-1], ng, gsz).all(axis=-1)
            cnt_g = counts.reshape(B, ng, gsz).sum(axis=-1)  # (B, ng)
            den_g = jnp.maximum(cnt_g, 1.0)[:, None, :, None]
            kmean_g = (pre.k_ds * counts[:, None, :, None]).reshape(
                B, Hkv, ng, gsz, D).sum(axis=3) / den_g
            vmean_g = (v_ds * counts[:, None, :, None]).reshape(
                B, Hkv, ng, gsz, D).sum(axis=3) / den_g
            mu_g = jnp.einsum("bhgcd,bhyd->bhgcy", qg, kmean_g) * scale
            wg = jnp.where(grp, jnp.exp(mu_g - c[..., None]), 0.0)
            wg = wg * cnt_g[:, None, None, None, :] * adj[..., None]
            out = out + jnp.einsum("bhgcy,bhyd->bhgcd", wg, vmean_g)
            rs = rs + jnp.sum(wg, axis=-1)
            bg = bg & ~jnp.repeat(grp, gsz, axis=-1)
        w = jnp.where(bg, jnp.exp(coarse_m - c[..., None]), 0.0)
        w = w * counts[:, None, None, None, :] * adj[..., None]
        out = out + jnp.einsum("bhgcy,bhyd->bhgcd", w, v_ds)
        rs = rs + jnp.sum(w, axis=-1)
        if up is not None:
            wh = jnp.where(hlive, jnp.exp(hmu - c[..., None]), 0.0)
            wh = wh * up.counts[:, None, None, None, :] * adj[..., None]
            out = out + jnp.einsum(
                "bhgcy,bhyd->bhgcd", wh, up.v_mean.astype(cdt))
            rs = rs + jnp.sum(wh, axis=-1)

    alive = rs > 0
    out = jnp.where(alive[..., None], out, 0.0) / jnp.where(alive, rs, 1.0)[..., None]
    return out.reshape(B, Hq, C, D).astype(q.dtype)


def full_chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_pos: jax.Array,
    *,
    softmax_scale: Optional[float] = None,
    compute_dtype=jnp.float32,
    local_window: Optional[int] = None,
) -> jax.Array:
    """Exact chunked-prefill attention oracle: C queries vs. a dense cache.

    Each query at position p attends keys at positions <= p (optionally
    restricted to the last ``local_window`` positions). O(C*S) per chunk.
    """
    B, Hq, C, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, Hkv, G, C, D).astype(compute_dtype)
    s = jnp.einsum("bhgcd,bhjd->bhgcj", qg, k_cache.astype(compute_dtype)) * scale
    kp = jnp.arange(S)
    ok = (kp[None, None, :] <= q_pos[:, :, None]) & (kp[None, None, :] < lengths[:, None, None])
    if local_window is not None:
        ok = ok & (kp[None, None, :] > q_pos[:, :, None] - local_window)
    s = jnp.where(ok[:, None, None], s, NEG_INF)  # (B,1,1,C,S) -> broadcast
    p = jax.nn.softmax(s, axis=-1)
    has = jnp.any(ok, axis=-1)[:, None, None]  # all-masked rows -> zeros
    out = jnp.einsum("bhgcj,bhjd->bhgcd", p, v_cache.astype(compute_dtype))
    out = jnp.where(has[..., None], out, 0.0)
    return out.reshape(B, Hq, C, D).astype(q.dtype)


def full_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    softmax_scale: Optional[float] = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Exact decode attention oracle. O(S) per token.

    Length-0 slots have every key masked; softmax over the finite ``NEG_INF``
    sentinel would be uniform and return a garbage V-average, so all-masked
    rows are zeroed — the same contract as ``full_chunk_attention`` (and as
    the MRA paths' ``alive`` guard), pinned by tests/test_engine.py.
    """
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, Hkv, G, D).astype(compute_dtype)
    s = jnp.einsum("bhgd,bhjd->bhgj", qg, k_cache.astype(compute_dtype)) * scale
    s = jnp.where((jnp.arange(S) < lengths[:, None])[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgj,bhjd->bhgd", p, v_cache.astype(compute_dtype))
    has = (lengths > 0)[:, None, None, None]  # all-masked rows -> zeros
    out = jnp.where(has, out, 0.0)
    return out.reshape(B, Hq, 1, D).astype(q.dtype)
