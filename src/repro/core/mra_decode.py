"""MRA-2 attention for autoregressive decode (one query vs. a long KV cache).

The paper evaluates bidirectional encoders; this module is the beyond-paper
adaptation of the same two-level scheme to decoding (DESIGN.md §7): the KV
cache is viewed as ``nb = S/b`` key blocks, coarse scores
``mu_y = exp(q (K~_b)_y^T * scale)`` pick the top-``m`` blocks for *exact*
attention, all remaining valid blocks contribute the coarse background
(``variant="full"``) exactly as in the prefill formulation with a 1-row query
block. Complexity per decoded token: O(S/b + m*b) instead of O(S) — this is
what makes the ``long_500k`` shapes sub-quadratic end-to-end.

An incrementally-maintained block-sum pyramid (``PyramidState``) makes the
coarse scores O(1) to update per appended token instead of O(S) to recompute.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .mra import MraConfig, NEG_INF, FORCE_BONUS


class PyramidState(NamedTuple):
    """Incremental block-sum pyramid over the KV cache.

    k_sum / v_sum: (B, Hkv, nb, D) running sums of keys/values per block.
    The block mean is ``sum / count`` with ``count`` derived from ``length``.
    """

    k_sum: jax.Array
    v_sum: jax.Array

    @staticmethod
    def init(batch: int, kv_heads: int, nb: int, d: int, dtype=jnp.float32):
        z = jnp.zeros((batch, kv_heads, nb, d), dtype)
        return PyramidState(z, z)

    def append(self, k_new: jax.Array, v_new: jax.Array, pos: jax.Array, block: int):
        """Add one token's K/V at position ``pos`` (per-batch array (B,))."""
        blk = pos // block  # (B,)
        b_idx = jnp.arange(self.k_sum.shape[0])
        k_sum = self.k_sum.at[b_idx, :, blk].add(k_new.astype(self.k_sum.dtype))
        v_sum = self.v_sum.at[b_idx, :, blk].add(v_new.astype(self.v_sum.dtype))
        return PyramidState(k_sum, v_sum)


def block_counts(lengths: jax.Array, nb: int, block: int) -> jax.Array:
    """(B, nb) number of valid tokens per key block given valid ``lengths``."""
    starts = jnp.arange(nb) * block
    return jnp.clip(lengths[:, None] - starts[None, :], 0, block)


def quantize_kv(x: jax.Array):
    """Per-token-per-head int8 quantization. x (B,H,*,D) -> (int8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def mra2_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    cfg: MraConfig,
    *,
    decode_blocks: int = 16,
    pyramid: Optional[PyramidState] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """One-step decode attention with MRA block selection.

    Args:
      q: (B, Hq, 1, D) the new token's query.
      k_cache/v_cache: (B, Hkv, S, D), S a static multiple of cfg.block_size.
      lengths: (B,) valid prefix length (includes the token being decoded).
      cfg: MraConfig (block_size, variant, compute dtype are honored).
      decode_blocks: selection budget m (number of exact key blocks).
      pyramid: optional incremental block sums; recomputed from the cache
        when absent.
      k_scale/v_scale: (B, Hkv, S) per-token dequant scales when the cache is
        int8 (§Perf Y3); gathered blocks are dequantized after the gather.

    Returns:
      (B, Hq, 1, D) attention output.
    """
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    b = cfg.block_size
    assert S % b == 0, (S, b)
    nb = S // b
    m = min(decode_blocks, nb)
    scale = cfg.softmax_scale if cfg.softmax_scale is not None else 1.0 / (D**0.5)
    cdt = cfg.compute_dtype

    counts = block_counts(lengths, nb, b).astype(cdt)  # (B, nb)
    if pyramid is None:
        mask = (jnp.arange(S) < lengths[:, None]).astype(k_cache.dtype)  # (B, S)
        k_sum = jnp.sum(
            (k_cache * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D),
            axis=3, dtype=cdt,
        )
        v_sum = jnp.sum(
            (v_cache * mask[:, None, :, None]).reshape(B, Hkv, nb, b, D),
            axis=3, dtype=cdt,
        )
    else:
        k_sum, v_sum = pyramid.k_sum.astype(cdt), pyramid.v_sum.astype(cdt)

    denom = jnp.maximum(counts, 1.0)[:, None, :, None]
    k_ds = k_sum / denom  # (B, Hkv, nb, D)
    v_ds = v_sum / denom

    qg = q.reshape(B, Hkv, G, D).astype(cdt)
    coarse = jnp.einsum("bhgd,bhyd->bhgy", qg, k_ds) * scale  # (B, Hkv, G, nb)
    valid = counts > 0  # (B, nb)
    coarse_m = jnp.where(valid[:, None, None, :], coarse, NEG_INF)

    # always select the newest (possibly partial) block: exact local context and
    # the only partially-filled block, so background blocks are always full.
    last_blk = jnp.clip((lengths - 1) // b, 0, nb - 1)  # (B,)
    sel_scores = coarse_m + FORCE_BONUS * (
        jnp.arange(nb)[None, None, None, :] == last_blk[:, None, None, None]
    )
    top_vals, y_idx = jax.lax.top_k(sel_scores, m)  # (B, Hkv, G, m)
    sel_ok = top_vals > NEG_INF * 0.5

    c = jnp.maximum(jnp.max(coarse_m, axis=-1), NEG_INF * 0.5)  # (B, Hkv, G)

    # ---- exact term over selected blocks -----------------------------------
    # gather in the cache dtype and cast the (small) gathered blocks only:
    # casting the whole cache first materializes a full fp32 copy (16 GiB at
    # 32k x 128 batch) and blocks buffer donation — §Perf iteration Y1.
    k_blocks = k_cache.reshape(B, Hkv, nb, b, D)
    v_blocks = v_cache.reshape(B, Hkv, nb, b, D)
    gidx = jnp.broadcast_to(y_idx[..., None, None], y_idx.shape + (1, 1))
    k_sel = jnp.take_along_axis(k_blocks[:, :, None], gidx, axis=3).astype(cdt)
    v_sel = jnp.take_along_axis(v_blocks[:, :, None], gidx, axis=3).astype(cdt)
    if k_scale is not None:  # int8 cache: dequantize the gathered blocks only
        gs = jnp.broadcast_to(y_idx[..., None], y_idx.shape + (1,))
        ks = jnp.take_along_axis(
            k_scale.reshape(B, Hkv, nb, b)[:, :, None], gs, axis=3).astype(cdt)
        vs = jnp.take_along_axis(
            v_scale.reshape(B, Hkv, nb, b)[:, :, None], gs, axis=3).astype(cdt)
        k_sel = k_sel * ks[..., None]
        v_sel = v_sel * vs[..., None]

    s = jnp.einsum("bhgd,bhgmjd->bhgmj", qg, k_sel) * scale  # (B,Hkv,G,m,b)
    pos = y_idx[..., None] * b + jnp.arange(b)  # (B,Hkv,G,m,b) global positions
    ok = (pos < lengths[:, None, None, None, None]) & sel_ok[..., None]
    # two-level stabilizer (see mra.py): per-query max over the selected
    # blocks' true scores, combined with the coarse max.
    fine_max = jnp.max(jnp.where(ok, s, NEG_INF), axis=(-1, -2))
    c_tok = jax.lax.stop_gradient(jnp.maximum(c, fine_max))  # (B,Hkv,G)
    adj = jnp.exp(c - c_tok)
    a = jnp.where(ok, jnp.exp(jnp.minimum(s - c_tok[..., None, None], 80.0)), 0.0)
    out = jnp.einsum("bhgmj,bhgmjd->bhgd", a, v_sel)
    rs = jnp.sum(a, axis=(-1, -2))  # (B,Hkv,G)

    # ---- coarse background ---------------------------------------------------
    if cfg.variant == "full":
        sel_grid = jnp.zeros((B, Hkv, G, nb), bool)
        sel_grid = jax.vmap(jax.vmap(jax.vmap(lambda z, i, val: z.at[i].set(val))))(
            sel_grid, y_idx, sel_ok
        )
        bg = valid[:, None, None, :] & ~sel_grid
        w = jnp.where(bg, jnp.exp(coarse_m - c[..., None]), 0.0) * counts[:, None, None, :]
        w = w * adj[..., None]
        out = out + jnp.einsum("bhgy,bhyd->bhgd", w, v_ds)
        rs = rs + jnp.sum(w, axis=-1)

    alive = rs > 0
    out = jnp.where(alive[..., None], out, 0.0) / jnp.where(alive, rs, 1.0)[..., None]
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def full_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    softmax_scale: Optional[float] = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Exact decode attention oracle. O(S) per token."""
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, Hkv, G, D).astype(compute_dtype)
    s = jnp.einsum("bhgd,bhjd->bhgj", qg, k_cache.astype(compute_dtype)) * scale
    s = jnp.where((jnp.arange(S) < lengths[:, None])[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgj,bhjd->bhgd", p, v_cache.astype(compute_dtype))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)
