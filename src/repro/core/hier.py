"""H-level pyramid: collapse-up hierarchy over evicted ring pages.

The MRA-2 decode path (core/mra_decode.py) is two-level: exact fine blocks
in the ring cache plus one layer of per-page coarse sums (the pyramid).
This module generalizes it to ``levels = H`` (DESIGN.md §14): pages that
fall out of the fine window are not dropped — their pyramid sums *collapse
up* into a telescoping stack of coarser rings, so arbitrarily long history
stays reachable as background mass at geometrically coarsening resolution.

Level geometry (b = block_size, nb = fine pages):

  * level 0 — the fine ring itself: exact K/V tokens, ``nb`` pages of ``b``.
  * level 1 — the live pyramid: one fp32 K/V sum per fine page (as today).
  * level ``l`` in ``[2, H)`` — a ring of ``n_l`` entries over *evicted*
    history; entry ``e`` aggregates fine blocks ``[e*2^(l-1), (e+1)*2^(l-1))``
    i.e. spans ``2^(l-1) * b`` tokens, doubling per level.
  * tail — a single fp32 sum + count absorbing everything evicted past the
    top level, so no token mass is ever lost (total-sum conservation is a
    property test).

Collapse-up rule: when fine block ``g`` is evicted, its pyramid sums carry
into level-2 entry ``g >> 1`` at physical slot ``(g >> 1) % n_2``. If that
slot holds a different owner, the old entry's mass cascades one level up
(entry id halves again), and so on into the tail — a carry chain, one slot
touched per level. Within one prefill chunk (C <= window - b) all evicted
blocks land in distinct level-2 slots, so batched collapse is
order-invariant; rounds are still applied oldest-block-first so cascades
match sequential decode exactly (the spec-rewind replay relies on this).

Quantization schedule: level 1 stays fp32; level 2 stores int8 means
(qmax 127); levels >= 3 store int4-precision means in int8 containers
(qmax 7 — jnp has no reliable int4 array dtype on CPU backends, so the
container stays int8 and the clip range enforces int4 precision); the tail
is fp32. Entry payloads are means + a per-entry scale; sums are always
reconstructed as ``mean * count`` with dead entries (count 0) contributing
exact zeros, so stale payload bytes after a slot reset are harmless.

Cache layout (keys added by models/transformer.cache_specs at H >= 3; the
serve layer resets/snapshots/rewinds them in serve/cache/paged.py):

  * per layer (lists over layers): ``hier_k{l}``/``hier_v{l}`` int8
    (B, Hkv, n_l, D) quantized means; ``hier_ks{l}``/``hier_vs{l}`` fp32
    (B, Hkv, n_l) scales; ``tail_k``/``tail_v`` fp32 (B, Hkv, D) sums.
  * shared (one array, like ``page_blocks``): ``hier_own{l}`` (B, n_l)
    int32 entry owner (-1 dead), ``hier_cnt{l}`` (B, n_l) int32 token
    counts, ``tail_cnt`` (B,) int32.

Attention consumes the whole stack through one ``HierUpper`` view (per-entry
dequantized means + token counts, all levels and the tail concatenated):
collapsed entries are strictly older than every live query, so the fold is
causal-mask-free — liveness (count > 0) is the only gate.
"""
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

_DEAD = jnp.int32(2**31 - 1)  # sort sentinel: absent eviction slots


class HierUpper(NamedTuple):
    """Dequantized view of every collapsed level + the tail, concatenated.

    k_mean/v_mean: (B, Hkv, NU, D) fp32 per-entry mean key/value.
    counts: (B, NU) fp32 token count per entry (0 = dead entry).
    NU = sum(n_l for l in 2..H-1) + 1 (the tail) — static per config.
    """

    k_mean: jax.Array
    v_mean: jax.Array
    counts: jax.Array


class LevelPlan(NamedTuple):
    """Value-independent collapse decisions at one level (all (B,))."""

    slot: jax.Array     # int32 physical slot touched at this level
    on: jax.Array       # bool: a carry lands at this level
    reset: jax.Array    # bool: slot content replaced (fresh claim or evict)
    old_cnt: jax.Array  # int32 slot count before the update
    new_cnt: jax.Array  # int32 slot count after the update


class CollapsePlan(NamedTuple):
    levels: tuple       # tuple[LevelPlan, ...] bottom-up
    tail_on: jax.Array  # (B,) bool: a carry reached the tail
    tail_cnt: jax.Array  # (B,) int32 token count folded into the tail


def level_qmax(level: int) -> float:
    """Quantization ceiling per level: int8 near (l=2), int4 far (l>=3)."""
    return 127.0 if level == 2 else 7.0


def hier_level_ids(cache) -> tuple:
    """Collapsed-level ids present in a cache mapping (sorted, () at H=2)."""
    pre = "hier_own"
    return tuple(sorted(int(k[len(pre):]) for k in cache if k.startswith(pre)))


def has_hier(cache) -> bool:
    return "tail_cnt" in cache


def quantize_mean(mean: jax.Array, qmax: float):
    """Per-entry symmetric quantization of a (…, D) mean. -> (int8, scale)."""
    amax = jnp.max(jnp.abs(mean.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(mean.astype(jnp.float32) / scale[..., None]),
                 -qmax, qmax)
    return q.astype(jnp.int8), scale


def collapse_tables(
    owners: Sequence[jax.Array],
    counts: Sequence[jax.Array],
    tail_cnt: jax.Array,
    blk: jax.Array,
    child_cnt: jax.Array,
    present: jax.Array,
):
    """Run the carry chain on the shared owner/count tables (value-free).

    The plan this returns drives the per-layer value update
    (``collapse_values``) — the split lets the tables update once per
    eviction while every layer's sums replay the same decisions.

    Args:
      owners/counts: per-level (B, n_l) tables, bottom level (l=2) first.
      tail_cnt: (B,) int32.
      blk: (B,) evicted fine-block id (garbage where ``present`` is False).
      child_cnt: (B,) token count of the evicted block (``b`` in the ring).
      present: (B,) bool — whether this batch row evicts anything.

    Returns:
      (new_owners, new_counts, new_tail_cnt, CollapsePlan).
    """
    b_idx = jnp.arange(blk.shape[0])
    eid = jnp.where(present, blk, 0) >> 1
    cc = child_cnt.astype(jnp.int32)
    on = present
    new_owners, new_counts, plans = list(owners), list(counts), []
    for li in range(len(new_owners)):
        n = new_owners[li].shape[1]
        slot = eid % n
        own = new_owners[li][b_idx, slot]
        oldc = new_counts[li][b_idx, slot]
        match = on & (own == eid)
        evict = on & ~match & (own >= 0)
        reset = on & ~match
        newc = jnp.where(reset, 0, oldc) + jnp.where(on, cc, 0)
        new_owners[li] = new_owners[li].at[b_idx, slot].set(
            jnp.where(on, eid, own))
        new_counts[li] = new_counts[li].at[b_idx, slot].set(
            jnp.where(on, newc, oldc))
        plans.append(LevelPlan(slot, on, reset, oldc, newc))
        eid = jnp.where(evict, own, 0) >> 1
        cc = oldc
        on = evict
    new_tail = tail_cnt + jnp.where(on, cc, 0)
    return new_owners, new_counts, new_tail, CollapsePlan(
        tuple(plans), on, cc)


def collapse_values(
    kq: Sequence[jax.Array],
    vq: Sequence[jax.Array],
    ks: Sequence[jax.Array],
    vs: Sequence[jax.Array],
    tail_k: jax.Array,
    tail_v: jax.Array,
    plan: CollapsePlan,
    child_k: jax.Array,
    child_v: jax.Array,
    qmaxs: Optional[Sequence[float]],
):
    """Apply one collapse plan to one layer's payload arrays.

    kq/vq: per-level (B, Hkv, n_l, D) stored means (int8 or fp32);
    ks/vs: per-level (B, Hkv, n_l) scales; tail_k/tail_v: (B, Hkv, D) sums;
    child_k/child_v: (B, Hkv, D) fp32 *sums* of the evicted fine block.
    qmaxs: per-level quantization ceilings, or None to store exact fp32
    means with unit scales (the property tests run unquantized).
    """
    b_idx = jnp.arange(child_k.shape[0])
    carry_k = child_k.astype(jnp.float32)
    carry_v = child_v.astype(jnp.float32)
    kq, vq, ks, vs = list(kq), list(vq), list(ks), list(vs)
    for li, p in enumerate(plan.levels):
        oldc = p.old_cnt.astype(jnp.float32)[:, None, None]
        newc = jnp.maximum(p.new_cnt, 1).astype(jnp.float32)[:, None, None]
        on3 = p.on[:, None, None]
        out_sums = []
        for store, scale, carry in ((kq, ks, carry_k), (vq, vs, carry_v)):
            old_q = store[li][b_idx, :, p.slot]
            old_s = scale[li][b_idx, :, p.slot]
            old_sum = old_q.astype(jnp.float32) * old_s[..., None] * oldc
            new_sum = (jnp.where(p.reset[:, None, None], 0.0, old_sum)
                       + jnp.where(on3, carry, 0.0))
            mean = new_sum / newc
            if qmaxs is None:
                q, s = mean.astype(store[li].dtype), jnp.ones_like(old_s)
            else:
                q, s = quantize_mean(mean, qmaxs[li])
                q = q.astype(store[li].dtype)
            store[li] = store[li].at[b_idx, :, p.slot].set(
                jnp.where(on3, q, old_q))
            scale[li] = scale[li].at[b_idx, :, p.slot].set(
                jnp.where(p.on[:, None], s, old_s))
            out_sums.append(old_sum)
        carry_k, carry_v = out_sums
    t_on = plan.tail_on[:, None, None]
    tail_k = tail_k + jnp.where(t_on, carry_k, 0.0)
    tail_v = tail_v + jnp.where(t_on, carry_v, 0.0)
    return kq, vq, ks, vs, tail_k, tail_v


def upper_view(
    kq: Sequence[jax.Array],
    vq: Sequence[jax.Array],
    ks: Sequence[jax.Array],
    vs: Sequence[jax.Array],
    counts: Sequence[jax.Array],
    tail_k: jax.Array,
    tail_v: jax.Array,
    tail_cnt: jax.Array,
) -> HierUpper:
    """Assemble the dequantized all-levels + tail view attention consumes."""
    km = [q.astype(jnp.float32) * s[..., None] for q, s in zip(kq, ks)]
    vm = [q.astype(jnp.float32) * s[..., None] for q, s in zip(vq, vs)]
    tden = jnp.maximum(tail_cnt, 1).astype(jnp.float32)[:, None, None, None]
    km.append(tail_k.astype(jnp.float32)[:, :, None] / tden)
    vm.append(tail_v.astype(jnp.float32)[:, :, None] / tden)
    cnt = [c.astype(jnp.float32) for c in counts]
    cnt.append(tail_cnt.astype(jnp.float32)[:, None])
    return HierUpper(jnp.concatenate(km, axis=2), jnp.concatenate(vm, axis=2),
                     jnp.concatenate(cnt, axis=1))


def eviction_schedule(old_pb: jax.Array, fresh: jax.Array, rounds: int):
    """Order a batch of evictions oldest-first for sequential collapse.

    old_pb: (B, nb) pre-update page table; fresh: (B, nb) pages recycled by
    the incoming writes. Returns ``rounds`` pairs ``(blk (B,), on (B,))`` —
    the j-th oldest evicted owner per batch row (ascending block id keeps
    cascades identical to one-eviction-at-a-time decode).
    """
    vals = jnp.where(fresh & (old_pb >= 0), old_pb, _DEAD)
    order = jnp.sort(vals, axis=1)
    return [(order[:, j], order[:, j] < _DEAD)
            for j in range(min(rounds, old_pb.shape[1]))]


# ---------------------------------------------------------------------------
# Cache-dict glue: models/transformer.py and serve/cache/paged.py drive the
# collapse through these, so the key layout lives in exactly one place.
# ---------------------------------------------------------------------------

def cache_collapse_tables(cache, blk, child_cnt, present):
    """collapse_tables over the shared ``hier_*``/``tail_cnt`` cache keys.

    Returns (updates dict, CollapsePlan); ``cache`` may be any mapping that
    holds the shared tables (a working copy merged over the real cache).
    """
    lids = hier_level_ids(cache)
    owners = [cache[f"hier_own{l}"] for l in lids]
    counts = [cache[f"hier_cnt{l}"] for l in lids]
    no, nc, tc, plan = collapse_tables(
        owners, counts, cache["tail_cnt"], blk, child_cnt, present)
    upd = {"tail_cnt": tc}
    for j, l in enumerate(lids):
        upd[f"hier_own{l}"] = no[j]
        upd[f"hier_cnt{l}"] = nc[j]
    return upd, plan


def cache_collapse_layer(cache, i, plan, child_k, child_v, *, quantize=True):
    """collapse_values for layer ``i``'s payload lists in the cache mapping.

    Returns a dict of the layer's updated arrays keyed by cache key (the
    caller re-slots them into the per-layer lists).
    """
    lids = hier_level_ids(cache)
    qmaxs = tuple(level_qmax(l) for l in lids) if quantize else None
    kq, vq, ks, vs, tk, tv = collapse_values(
        [cache[f"hier_k{l}"][i] for l in lids],
        [cache[f"hier_v{l}"][i] for l in lids],
        [cache[f"hier_ks{l}"][i] for l in lids],
        [cache[f"hier_vs{l}"][i] for l in lids],
        cache["tail_k"][i], cache["tail_v"][i],
        plan, child_k, child_v, qmaxs)
    upd = {"tail_k": tk, "tail_v": tv}
    for j, l in enumerate(lids):
        upd[f"hier_k{l}"] = kq[j]
        upd[f"hier_v{l}"] = vq[j]
        upd[f"hier_ks{l}"] = ks[j]
        upd[f"hier_vs{l}"] = vs[j]
    return upd


def cache_store_layer(cache, i, upd):
    """Re-slot a cache_collapse_layer update into the per-layer lists."""
    for key, arr in upd.items():
        vals = list(cache[key])
        vals[i] = arr
        cache[key] = vals


def cache_upper_view(cache, i) -> Optional[HierUpper]:
    """The HierUpper view for layer ``i``, or None when the cache is H=2."""
    lids = hier_level_ids(cache)
    if not has_hier(cache):
        return None
    return upper_view(
        [cache[f"hier_k{l}"][i] for l in lids],
        [cache[f"hier_v{l}"][i] for l in lids],
        [cache[f"hier_ks{l}"][i] for l in lids],
        [cache[f"hier_vs{l}"][i] for l in lids],
        [cache[f"hier_cnt{l}"] for l in lids],
        cache["tail_k"][i], cache["tail_v"][i], cache["tail_cnt"])


def build_hier_stream(
    k: jax.Array,
    v: jax.Array,
    *,
    block: int,
    nb: int,
    levels: int,
    hier_n: Optional[int] = None,
    num_layers: int = 1,
    quantize: bool = True,
):
    """Reference builder: stream (B, Hkv, S, D) K/V through an H-level ring.

    Sequentially writes each fine block into a ``nb``-page ring, collapsing
    the evicted owner up the hierarchy exactly as decode would — the shared
    oracle for the approx_error bench and the collapse property tests.
    Returns a dict shaped like the serve cache slice: ``k_cache``/``v_cache``
    (the live ring window), ``page_blocks``, ``pyr_k``/``pyr_v`` (per-layer
    lists, every layer identical), the ``hier_*``/``tail_*`` keys, and
    ``lengths``.
    """
    B, Hkv, S, D = k.shape
    if S % block:
        raise ValueError(f"S={S} must be a multiple of block={block}")
    n = hier_n or nb
    cache = {
        "k_cache": jnp.zeros((B, Hkv, nb * block, D), k.dtype),
        "v_cache": jnp.zeros((B, Hkv, nb * block, D), v.dtype),
        "page_blocks": jnp.full((B, nb), -1, jnp.int32),
        "pyr_k": [jnp.zeros((B, Hkv, nb, D), jnp.float32)] * num_layers,
        "pyr_v": [jnp.zeros((B, Hkv, nb, D), jnp.float32)] * num_layers,
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    if levels >= 3:
        pdtype = jnp.int8 if quantize else jnp.float32
        for l in range(2, levels):
            cache[f"hier_k{l}"] = [jnp.zeros((B, Hkv, n, D), pdtype)] * num_layers
            cache[f"hier_v{l}"] = [jnp.zeros((B, Hkv, n, D), pdtype)] * num_layers
            cache[f"hier_ks{l}"] = [jnp.zeros((B, Hkv, n))] * num_layers
            cache[f"hier_vs{l}"] = [jnp.zeros((B, Hkv, n))] * num_layers
            cache[f"hier_own{l}"] = jnp.full((B, n), -1, jnp.int32)
            cache[f"hier_cnt{l}"] = jnp.zeros((B, n), jnp.int32)
        cache["tail_k"] = [jnp.zeros((B, Hkv, D))] * num_layers
        cache["tail_v"] = [jnp.zeros((B, Hkv, D))] * num_layers
        cache["tail_cnt"] = jnp.zeros((B,), jnp.int32)

    ones = jnp.ones((B,), bool)
    for g in range(S // block):
        page = g % nb
        old_owner = cache["page_blocks"][:, page]
        ksum = cache["pyr_k"][0][:, :, page]
        vsum = cache["pyr_v"][0][:, :, page]
        if levels >= 3:
            present = ones & (old_owner >= 0)
            upd, plan = cache_collapse_tables(
                cache, old_owner, jnp.full((B,), block, jnp.int32), present)
            cache.update(upd)
            for i in range(num_layers):
                lay = cache_collapse_layer(cache, i, plan, ksum, vsum,
                                           quantize=quantize)
                cache_store_layer(cache, i, lay)
        kb = k[:, :, g * block:(g + 1) * block]
        vb = v[:, :, g * block:(g + 1) * block]
        sl = slice(page * block, (page + 1) * block)
        cache["k_cache"] = cache["k_cache"].at[:, :, sl].set(kb)
        cache["v_cache"] = cache["v_cache"].at[:, :, sl].set(vb)
        for i in range(num_layers):
            cache["pyr_k"] = list(cache["pyr_k"])
            cache["pyr_v"] = list(cache["pyr_v"])
            cache["pyr_k"][i] = cache["pyr_k"][i].at[:, :, page].set(
                kb.astype(jnp.float32).sum(axis=2))
            cache["pyr_v"][i] = cache["pyr_v"][i].at[:, :, page].set(
                vb.astype(jnp.float32).sum(axis=2))
        cache["page_blocks"] = cache["page_blocks"].at[:, page].set(g)
    return cache
