"""Efficient-attention baselines the paper compares against (§5), in JAX.

Each baseline is a parameter-free (randomness is seeded & static) function
``f(q, k, v, **kw) -> out`` with q (B, H, N, D), k/v (B, H, N, D), matching
the approximation-benchmark protocol of the paper (Fig. 4/5, Tab. 7): how
well does ``f`` approximate ``softmax(QK^T/sqrt(d)) V``? Learned-parameter
variants (Linformer's E/F, etc.) are modeled with fixed random projections,
which matches how the paper's own Fig. 4 treats approximation ability.

Baselines: Linformer, Performer (FAVOR+), Nystromformer, Longformer
(sliding window), BigBird (window+global+random), H-Transformer-1D
(hierarchical: exact diagonal + coarse off-diagonal — expressed with our own
MRA machinery with a *fixed* selection, demonstrating that H-matrices are a
special case of the MRA frame, paper §2.1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .mra import NEG_INF, block_mean, full_attention


def _scale(d: int, softmax_scale: Optional[float]) -> float:
    return softmax_scale if softmax_scale is not None else 1.0 / (d**0.5)


# --------------------------------------------------------------------------- #
# Low-rank family
# --------------------------------------------------------------------------- #
def linformer_attention(q, k, v, *, proj_dim: int = 64, seed: int = 0, softmax_scale=None):
    """Linformer (Wang et al., 2020): project the length axis of K/V to k dims."""
    B, H, N, D = q.shape
    key = jax.random.PRNGKey(seed)
    E = jax.random.normal(key, (N, proj_dim), jnp.float32) / (proj_dim**0.5)
    kp = jnp.einsum("bhnd,nk->bhkd", k.astype(jnp.float32), E)
    vp = jnp.einsum("bhnd,nk->bhkd", v.astype(jnp.float32), E)
    s = jnp.einsum("bhid,bhkd->bhik", q.astype(jnp.float32), kp) * _scale(D, softmax_scale)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhik,bhkd->bhid", p, vp).astype(q.dtype)


def performer_attention(q, k, v, *, num_features: int = 64, seed: int = 0, softmax_scale=None):
    """Performer FAVOR+ (Choromanski et al., 2021) positive random features."""
    B, H, N, D = q.shape
    sc = _scale(D, softmax_scale)
    key = jax.random.PRNGKey(seed)
    # orthogonal random features
    blocks = []
    n_full = num_features // D + 1
    for i in range(n_full):
        key, sub = jax.random.split(key)
        mat = jax.random.normal(sub, (D, D))
        qmat, _ = jnp.linalg.qr(mat)
        blocks.append(qmat.T)
    W = jnp.concatenate(blocks, axis=0)[:num_features]  # (m, D)
    norms = jnp.sqrt(jax.random.chisquare(key, D, (num_features,)))
    W = W * norms[:, None]

    def phi(x):
        x = x.astype(jnp.float32) * (sc**0.5)
        proj = jnp.einsum("bhnd,md->bhnm", x, W)
        sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
        return jnp.exp(proj - sq - jnp.max(proj, axis=-1, keepdims=True)) / (num_features**0.5)

    qf, kf = phi(q), phi(k)
    kv = jnp.einsum("bhnm,bhnd->bhmd", kf, v.astype(jnp.float32))
    z = 1.0 / (jnp.einsum("bhnm,bhm->bhn", qf, jnp.sum(kf, axis=2)) + 1e-9)
    return (jnp.einsum("bhnm,bhmd->bhnd", qf, kv) * z[..., None]).astype(q.dtype)


def nystromformer_attention(q, k, v, *, num_landmarks: int = 32, pinv_iters: int = 6,
                            softmax_scale=None):
    """Nystromformer (Xiong et al., 2021): landmark Nystrom approximation."""
    B, H, N, D = q.shape
    sc = _scale(D, softmax_scale)
    lm = num_landmarks
    assert N % lm == 0, (N, lm)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    q_l = block_mean(qf, N // lm)  # (B,H,lm,D) segment-mean landmarks
    k_l = block_mean(kf, N // lm)
    f = jax.nn.softmax(jnp.einsum("bhid,bhjd->bhij", qf, k_l) * sc, axis=-1)  # (N, lm)
    a = jax.nn.softmax(jnp.einsum("bhid,bhjd->bhij", q_l, k_l) * sc, axis=-1)  # (lm, lm)
    bmat = jax.nn.softmax(jnp.einsum("bhid,bhjd->bhij", q_l, kf) * sc, axis=-1)  # (lm, N)
    # iterative Moore-Penrose pseudo-inverse (Razavi et al.), as in the paper's code
    z = a.swapaxes(-1, -2) / (
        jnp.max(jnp.sum(jnp.abs(a), axis=-2), axis=-1)[..., None, None]
        * jnp.max(jnp.sum(jnp.abs(a), axis=-1), axis=-1)[..., None, None]
    )
    I = jnp.eye(lm, dtype=jnp.float32)
    for _ in range(pinv_iters):
        az = a @ z
        z = 0.25 * z @ (13 * I - az @ (15 * I - az @ (7 * I - az)))
    out = f @ (z @ (bmat @ v.astype(jnp.float32)))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Sparsity family
# --------------------------------------------------------------------------- #
def longformer_attention(q, k, v, *, window: int = 64, num_global: int = 0,
                         softmax_scale=None):
    """Longformer (Beltagy et al., 2020): sliding window + optional global tokens.

    Implemented as banded attention over shifted key blocks (window must be a
    multiple of the internal block). O(n * window).
    """
    B, H, N, D = q.shape
    sc = _scale(D, softmax_scale)
    w = window
    assert N % w == 0, (N, w)
    nb = N // w
    qf = q.reshape(B, H, nb, w, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = []
    vals = []
    for shift in (-1, 0, 1):
        kb = jnp.roll(kf.reshape(B, H, nb, w, D), -shift, axis=2)
        vb = jnp.roll(vf.reshape(B, H, nb, w, D), -shift, axis=2)
        ok = (jnp.arange(nb) + shift >= 0) & (jnp.arange(nb) + shift < nb)
        s = jnp.einsum("bhnid,bhnjd->bhnij", qf, kb) * sc
        # distance mask: |i_global - j_global| <= w/2 within the 3-block band
        qi = jnp.arange(w)[:, None]
        kj = jnp.arange(w)[None, :] + shift * w
        dist_ok = jnp.abs(qi - kj) <= w // 2
        s = jnp.where(dist_ok[None, None, None] & ok[None, None, :, None, None], s, NEG_INF)
        scores.append(s)
        vals.append(vb)
    s_all = jnp.concatenate(scores, axis=-1)  # (B,H,nb,w,3w)
    v_all = jnp.concatenate(vals, axis=-2)  # (B,H,nb,3w,D)
    if num_global > 0:
        sg = jnp.einsum("bhnid,bhjd->bhnij", qf, kf[:, :, :num_global]) * sc
        s_all = jnp.concatenate([s_all, sg], axis=-1)
        v_all = jnp.concatenate(
            [v_all, jnp.broadcast_to(vf[:, :, None, :num_global], (B, H, nb, num_global, D))],
            axis=-2,
        )
    p = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bhnij,bhnjd->bhnid", p, v_all)
    return out.reshape(B, H, N, D).astype(q.dtype)


def bigbird_attention(q, k, v, *, window: int = 64, num_global: int = 16,
                      num_random: int = 3, seed: int = 0, softmax_scale=None):
    """BigBird (Zaheer et al., 2020): window + global + random block attention."""
    B, H, N, D = q.shape
    sc = _scale(D, softmax_scale)
    w = window
    assert N % w == 0
    nb = N // w
    qf = q.reshape(B, H, nb, w, D).astype(jnp.float32)
    kb = k.reshape(B, H, nb, w, D).astype(jnp.float32)
    vb = v.reshape(B, H, nb, w, D).astype(jnp.float32)

    scores, vals = [], []
    for shift in (-1, 0, 1):
        kk = jnp.roll(kb, -shift, axis=2)
        vv = jnp.roll(vb, -shift, axis=2)
        ok = (jnp.arange(nb) + shift >= 0) & (jnp.arange(nb) + shift < nb)
        s = jnp.einsum("bhnid,bhnjd->bhnij", qf, kk) * sc
        s = jnp.where(ok[None, None, :, None, None], s, NEG_INF)
        scores.append(s)
        vals.append(vv)
    # random blocks (static, seeded)
    rng = jax.random.PRNGKey(seed)
    rand_idx = jax.random.randint(rng, (nb, num_random), 0, nb)  # (nb, r)
    kr = kb[:, :, rand_idx.reshape(-1)].reshape(B, H, nb, num_random * w, D)
    vr = vb[:, :, rand_idx.reshape(-1)].reshape(B, H, nb, num_random * w, D)
    scores.append(jnp.einsum("bhnid,bhnjd->bhnij", qf, kr) * sc)
    vals.append(vr)
    # global prefix tokens
    if num_global > 0:
        kg = k[:, :, :num_global].astype(jnp.float32)
        vg = v[:, :, :num_global].astype(jnp.float32)
        scores.append(jnp.einsum("bhnid,bhjd->bhnij", qf, kg) * sc)
        vals.append(jnp.broadcast_to(vg[:, :, None], (B, H, nb, num_global, D)))
    s_all = jnp.concatenate(scores, axis=-1)
    v_all = jnp.concatenate(vals, axis=-2)
    p = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bhnij,bhnjd->bhnid", p, v_all)
    return out.reshape(B, H, N, D).astype(q.dtype)


def h_transformer_1d_attention(q, k, v, *, block: int = 32, levels: int = 2,
                               softmax_scale=None):
    """H-Transformer-1D (Zhu & Soricut, 2021) as a *fixed-selection* MRA.

    Exact attention on the (block-)diagonal; off-diagonal regions approximated
    at successively coarser scales: distance-1 blocks at scale ``block``,
    everything farther at scale ``block * 2**(levels-1)`` ... — i.e. the MRA
    frame with a prespecified hierarchical J instead of a data-dependent one
    (paper §2.1's contrast).
    """
    from .mra import MraConfig, mra2_attention

    B, H, N, D = q.shape
    # emulate with the MRA machinery: force-diagonal selection with budget
    # equal to a tri-diagonal band; background handles the rest coarsely.
    cfg = MraConfig(block_size=block, blocks_per_row=3, variant="full",
                    force_diagonal=True, softmax_scale=softmax_scale)
    # Selection in mra2_attention is data-dependent (top-k); the H1D pattern is
    # its worst case when attention is banded. We keep the data-dependent J
    # but with the banded budget, which upper-bounds H1D fidelity per paper Fig 5.
    return mra2_attention(q, k, v, cfg)


REGISTRY = {
    "linformer": linformer_attention,
    "performer": performer_attention,
    "nystromformer": nystromformer_attention,
    "longformer": longformer_attention,
    "bigbird": bigbird_attention,
    "h_transformer_1d": h_transformer_1d_attention,
    "full": lambda q, k, v, **kw: full_attention(q, k, v, softmax_scale=kw.get("softmax_scale")),
}
