"""MRA-2 approximate self-attention (Zeng et al., ICML 2022), TPU-native JAX.

Implements the practical two-level instantiation R = {b, 1} used for every
experiment in the paper:

  * coarse scores  ``mu[x, y] = exp((Q~_b)_x (K~_b)_y^T * scale)``  on the
    (n/b, n/b) block grid (exp-of-average, Jensen lower bound of the block
    mean of exp, paper eq. (6)),
  * a budgeted top-k selection of blocks (Alg. 1 with R = {b, 1}) which are
    then evaluated *exactly* at scale 1,
  * the remaining blocks keep the coarse value as a low-rank-ish background
    (``variant="full"`` == MRA-2) or are dropped (``variant="sparse"`` ==
    MRA-2-s),
  * a matrix-free ``A_hat @ V`` (Alg. 2) that never materializes the n x n
    matrix.

All functions are jit-compatible: the block *budget* is static, only the
block *indices* are data-dependent, so shapes never change across steps.

Beyond-paper extensions (documented in DESIGN.md §7): causal masking with
block-level triangular selection, GQA-aware gathering without expanding KV
heads, per-query-block softmax stabilization derived from the coarse scores,
and optional key padding masks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9  # finite "minus infinity": exp(NEG_INF - c) underflows to 0, no NaNs
FORCE_BONUS = 2e9  # added to coarse scores of blocks that must be selected


@dataclasses.dataclass(frozen=True)
class MraConfig:
    """Configuration of the MRA-2 attention approximation.

    Attributes:
      block_size: side length b of the (scale-b) blocks. Paper uses 32; the
        TPU kernel path prefers 128 (one MXU tile per block).
      blocks_per_row: selection budget expressed as the average number of
        high-resolution blocks per query-block row; the total budget is
        ``blocks_per_row * ceil(n / b)``. Paper's Table 7 sweeps this.
      variant: "full" = MRA-2 (coarse background kept), "sparse" = MRA-2-s.
      causal: apply an autoregressive mask (block-triangular selection grid,
        exact masking inside diagonal blocks).
      force_diagonal: always include the diagonal blocks in the selected set
        (guarantees every query row has at least one exact block; required
        for numerical safety of the sparse variant and for causal decoding).
      softmax_scale: score scale; None -> 1/sqrt(head_dim).
      compute_dtype: dtype for score computation/accumulation.
      use_kernel: route the high-resolution block computation through the
        Pallas TPU kernels (kernels/block_sparse_attn), forward *and*
        backward; handles padded/masked sequences and causal selection, so
        it serves training and arbitrary-length traffic (DESIGN.md §3).
      kernel_bwd: backward implementation when use_kernel — "pallas" (fused
        recompute kernels) or "jnp" (gather/recompute fallback, kernels/ref).
      kernel_mode: serving-kernel tile shape (kernels/chunk_attn.py,
        DESIGN.md §11) — "latency" (single-query tiles, decode waves) |
        "throughput" (multi-query MXU tiles, prefill/verify chunks) |
        "auto" (resolved per dispatch at trace time from the chunk width).
        Ignored by the full-sequence training path.
      interpret: run the Pallas kernels in interpret mode (CPU validation).
      draft_level: resolution level of the coarse background fold on the
        decode/chunk path (DESIGN.md §14). 1 = per-page block means (the
        MRA-2 default); level ``l`` > 1 aggregates the background over
        groups of ``2^(l-1)`` physically adjacent ring pages (requires the
        page count to divide evenly), giving speculative drafts a
        progressively cheaper far field. Groups containing any exact /
        causally-partial page fall back to per-page background.
    """

    block_size: int = 32
    blocks_per_row: int = 4
    variant: str = "full"
    causal: bool = False
    force_diagonal: bool = True
    softmax_scale: Optional[float] = None
    compute_dtype: jnp.dtype = jnp.float32
    use_kernel: bool = False
    kernel_bwd: str = "pallas"
    kernel_mode: str = "auto"
    interpret: bool = False
    draft_level: int = 1

    def budget(self, n: int) -> int:
        nb = -(-n // self.block_size)
        want = self.blocks_per_row * nb
        if self.causal:
            max_blocks = nb * (nb + 1) // 2
        else:
            max_blocks = nb * nb
        return min(want, max_blocks)


def block_mean(x: jax.Array, block: int, *, axis: int = -2, dtype=None) -> jax.Array:
    """Mean-pool ``x`` along ``axis`` in non-overlapping windows of ``block``.

    This is the pyramid downsampling of paper eq. (7) specialized to one
    level (Q~_b / K~_b / V~_b from Q/K/V). ``dtype`` sets the accumulation
    dtype (fused into the reduce — no materialized full-tensor cast).
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    assert n % block == 0, f"length {n} not divisible by block {block}"
    new_shape = x.shape[:axis] + (n // block, block) + x.shape[axis + 1 :]
    return jnp.mean(x.reshape(new_shape), axis=axis + 1, dtype=dtype)


def block_sum(x: jax.Array, block: int, *, axis: int = -2, dtype=None) -> jax.Array:
    axis = axis % x.ndim
    n = x.shape[axis]
    assert n % block == 0
    new_shape = x.shape[:axis] + (n // block, block) + x.shape[axis + 1 :]
    return jnp.sum(x.reshape(new_shape), axis=axis + 1, dtype=dtype)


def _pad_to_multiple(x: jax.Array, block: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % block
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _block_grid_mask(nb: int, causal: bool) -> jax.Array:
    """(nb, nb) boolean mask of *allowed* blocks on the selection grid."""
    if not causal:
        return jnp.ones((nb, nb), dtype=bool)
    r = jnp.arange(nb)
    return r[:, None] >= r[None, :]


def _fine_causal_mask(b: int) -> jax.Array:
    """(b, b) lower-triangular mask used inside diagonal blocks."""
    r = jnp.arange(b)
    return r[:, None] >= r[None, :]


def mra2_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: MraConfig,
    *,
    key_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """MRA-2 attention.

    Args:
      q: (B, Hq, N, D) queries.
      k: (B, Hkv, N, D) keys; Hq must be a multiple of Hkv (GQA).
      v: (B, Hkv, N, D) values.
      cfg: approximation config.
      key_mask: optional (B, N) boolean validity of keys (True = valid).

    Returns:
      (B, Hq, N, D) attention output in q.dtype.
    """
    orig_dtype = q.dtype
    B, Hq, N, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    b = cfg.block_size
    scale = cfg.softmax_scale if cfg.softmax_scale is not None else 1.0 / (D**0.5)
    cdt = cfg.compute_dtype

    q, _ = _pad_to_multiple(q, b, axis=2)
    k, _ = _pad_to_multiple(k, b, axis=2)
    v, _ = _pad_to_multiple(v, b, axis=2)
    n = q.shape[2]
    nb = n // b
    m = cfg.budget(n)

    if key_mask is None:
        key_mask = jnp.arange(n) < N
        key_mask = jnp.broadcast_to(key_mask[None], (B, n))
    else:
        key_mask, _ = _pad_to_multiple(key_mask, b, axis=1)

    km = key_mask.astype(cdt)  # (B, n)
    kcount = block_sum(km[..., None], b, axis=-2)[..., 0]  # (B, nb) valid keys per block
    has_valid = kcount > 0

    # ---- pyramid downsample (eq. 7, one level) --------------------------------
    # masked means so that padded keys do not skew the coarse scores. Keep the
    # full q/k/v tensors in their input dtype (casting the whole tensor to
    # fp32 materializes a full-size copy — §Perf iteration Y1); the compute
    # dtype is applied to the small downsampled tensors and gathered blocks.
    q_g = q.reshape(B, Hkv, G, n, D)
    k_c = k
    v_c = v
    kmn = km.astype(k.dtype)
    q_ds = block_mean(q_g, b, axis=-2, dtype=cdt)  # (B, Hkv, G, nb, D)
    k_ds = block_sum(k_c * kmn[:, None, :, None], b, axis=-2, dtype=cdt) / jnp.maximum(
        kcount[:, None, :, None], 1.0
    )  # (B, Hkv, nb, D)
    v_ds = block_sum(v_c * kmn[:, None, :, None], b, axis=-2, dtype=cdt) / jnp.maximum(
        kcount[:, None, :, None], 1.0
    )

    # ---- coarse scores mu (eq. 6) ---------------------------------------------
    coarse = jnp.einsum("bhgxd,bhyd->bhgxy", q_ds, k_ds) * scale  # (B,Hkv,G,nb,nb)
    allowed = _block_grid_mask(nb, cfg.causal)[None, None, None]  # (1,1,1,nb,nb)
    allowed = jnp.logical_and(allowed, has_valid[:, None, None, None, :])
    coarse_m = jnp.where(allowed, coarse, NEG_INF)

    # ---- selection (Alg. 1, R = {b, 1}) ----------------------------------------
    sel_scores = coarse_m
    if cfg.force_diagonal:
        eye = jnp.eye(nb, dtype=bool)[None, None, None]
        sel_scores = jnp.where(eye, coarse_m + FORCE_BONUS, coarse_m)
    flat = sel_scores.reshape(B, Hkv, G, nb * nb)
    top_vals, top_idx = jax.lax.top_k(flat, m)  # (B,Hkv,G,m)
    x_idx = top_idx // nb
    y_idx = top_idx % nb
    # blocks whose (possibly bonused) score is still NEG_INF were never allowed
    sel_valid = top_vals > (NEG_INF * 0.5)

    # ---- background support -----------------------------------------------------
    # Needed both for the low-res term and for the stabilizer: c_bg is the
    # max coarse score among *background* blocks — rows whose background is
    # empty must not be stabilized above their own fine scores, or every exp
    # underflows and the row dies; see tests. Both high-res paths derive the
    # exact per-token stabilizer c_tok = max(fine row max, c_bg) from it.
    sel_grid = jnp.zeros((B, Hkv, G, nb * nb), bool)
    sel_grid = jax.vmap(jax.vmap(jax.vmap(lambda z, i, val: z.at[i].set(val))))(
        sel_grid, top_idx, sel_valid
    )
    sel_grid = sel_grid.reshape(B, Hkv, G, nb, nb)
    bg = jnp.logical_and(allowed, ~sel_grid)
    if cfg.variant == "full":
        c_bg = jnp.max(jnp.where(bg, coarse_m, NEG_INF), axis=-1)  # (B,Hkv,G,nb)
    else:
        c_bg = jnp.full((B, Hkv, G, nb), NEG_INF)

    # ---- high-resolution term ---------------------------------------------------
    if cfg.use_kernel:
        # Pallas TPU path (kernels/block_sparse_attn.py), fwd + fused bwd.
        # Key padding rides into the kernel as a per-key-block mask tile, so
        # arbitrary lengths / masked traffic stay on the kernel. The kernel
        # raises the c_bg floor to the exact per-token score max online
        # (flash-style rescaling) and emits it as mt == c_tok — the same
        # two-level stabilizer as the jnp path, so the paths agree to fp32
        # rounding and neither fwd nor bwd can overflow.
        from repro.kernels.ops import block_sparse_attention

        flags = sel_valid.astype(jnp.int32)
        if cfg.causal:
            flags = flags | (2 * (x_idx == y_idx)).astype(jnp.int32)
        BHG = B * Hkv * G
        km_kv = jnp.broadcast_to(key_mask[:, None], (B, Hkv, n)).reshape(
            B * Hkv, n
        ).astype(jnp.int32)
        c_floor = jnp.maximum(c_bg, NEG_INF * 0.5)  # keep exp args finite
        out_f, rs_f, mt_f = block_sparse_attention(
            q_g.reshape(BHG, n, D),
            k_c.reshape(B * Hkv, n, D),
            v_c.reshape(B * Hkv, n, D),
            c_floor.reshape(BHG, nb).astype(jnp.float32),
            x_idx.reshape(BHG, m).astype(jnp.int32),
            y_idx.reshape(BHG, m).astype(jnp.int32),
            flags.reshape(BHG, m),
            km_kv,
            scale=scale,
            block_size=b,
            interpret=cfg.interpret,
            bwd_impl=cfg.kernel_bwd,
        )
        out_hr = out_f.reshape(B, Hkv, G, nb, b, D)
        rs_hr = rs_f.reshape(B, Hkv, G, nb, b)
        mt = jax.lax.stop_gradient(mt_f).reshape(B, Hkv, G, nb, b)
        # adj = exp(c_bg - c_tok): rescales the block-stabilized background
        # onto the kernel's per-token stabilizer (min guards c_bg = NEG_INF
        # against the c_floor clamp)
        adj = jnp.exp(jnp.minimum(c_bg[..., None] - mt, 0.0)).astype(cdt)
        c_base = c_bg
    else:
        out_hr, rs_hr, adj = _high_res_jnp(
            q_g, k_c, v_c, km, c_bg, x_idx, y_idx, sel_valid, cfg, scale, nb
        )
        c_base = c_bg

    # ---- low-resolution background (Alg. 2 coarse level) -----------------------
    if cfg.variant == "full":
        c_safe = jnp.maximum(c_base, NEG_INF * 0.5)[..., None]
        # clamp at 0: exact on the background support (coarse_m <= c_bg there)
        # and keeps the off-support exp finite (where-grad 0*inf guard)
        a_lr = jnp.where(bg, jnp.exp(jnp.minimum(coarse_m - c_safe, 0.0)), 0.0)
        w_lr = a_lr * kcount[:, None, None, None, :]  # sum over block = mu * (#valid keys)
        out_lr = jnp.einsum("bhgxy,bhyd->bhgxd", w_lr, v_ds)  # (B,Hkv,G,nb,D)
        rs_lr = jnp.sum(w_lr, axis=-1)  # (B,Hkv,G,nb)
        # adj = exp(c_base - c_tok) rescales the block-stabilized background to
        # the per-token stabilizer (two-level stabilization; see _high_res_jnp)
        out_hr = out_hr + adj[..., None] * out_lr[..., None, :]
        rs_hr = rs_hr + adj * rs_lr[..., None]

    # guarded normalization: rows can only be empty in pathological configs
    # (no forced diagonal); never let a ~0 denominator explode gradients
    alive = rs_hr > 0
    out = jnp.where(alive[..., None], out_hr, 0.0) / jnp.where(alive, rs_hr, 1.0)[..., None]
    out = out.reshape(B, Hq, n, D)[:, :, :N]
    return out.astype(orig_dtype)


def _high_res_jnp(q_g, k_c, v_c, km, c_bg, x_idx, y_idx, sel_valid, cfg, scale, nb):
    """Gather-einsum-scatter implementation of the high-resolution term.

    ``c_bg`` is the per-query-block max coarse score over *background* blocks
    (NEG_INF when the background is empty / the sparse variant). The token
    stabilizer is c_tok = max(fine row max, c_bg) — the max over everything
    that actually enters the softmax, so the largest term is exp(0) = 1 and
    rows can neither overflow nor underflow to zero.
    """
    B, Hkv, G, n, D = q_g.shape
    b = cfg.block_size
    cdt = cfg.compute_dtype
    q_blocks = q_g.reshape(B, Hkv, G, nb, b, D)
    k_blocks = k_c.reshape(B, Hkv, nb, b, D)
    v_blocks = v_c.reshape(B, Hkv, nb, b, D)
    km_blocks = km.reshape(B, nb, b)

    # gather in input dtype, cast the gathered blocks only (§Perf Y1: casting
    # the full tensors first materializes fp32 copies of q/k/v)
    q_sel = jnp.take_along_axis(
        q_blocks, x_idx[..., None, None], axis=3
    ).astype(cdt)  # (B,Hkv,G,m,b,D)
    k_sel = jnp.take_along_axis(
        k_blocks[:, :, None], jnp.broadcast_to(y_idx[..., None, None], y_idx.shape + (1, 1)), axis=3
    ).astype(cdt)  # (B,Hkv,G,m,b,D) via broadcast of k over G
    v_sel = jnp.take_along_axis(
        v_blocks[:, :, None], jnp.broadcast_to(y_idx[..., None, None], y_idx.shape + (1, 1)), axis=3
    ).astype(cdt)
    km_sel = jnp.take_along_axis(
        km_blocks[:, None, None], jnp.broadcast_to(y_idx[..., None], y_idx.shape + (1,)), axis=3
    )  # (B,Hkv,G,m,b)

    s = jnp.einsum("bhgmid,bhgmjd->bhgmij", q_sel, k_sel) * scale  # (B,Hkv,G,m,b,b)
    fine_ok = km_sel[..., None, :] > 0  # key validity within block
    if cfg.causal:
        diag = (x_idx == y_idx)[..., None, None]
        tri = _fine_causal_mask(b)[None, None, None, None]
        fine_ok = jnp.logical_and(fine_ok, jnp.logical_or(~diag, tri))
    fine_ok = jnp.logical_and(fine_ok, sel_valid[..., None, None])

    def _seg_add(z, i, u):
        return z.at[i].add(u)

    def _seg_max(z, i, u):
        return z.at[i].max(u)

    seg = jax.vmap(jax.vmap(jax.vmap(_seg_add)))
    seg_max = jax.vmap(jax.vmap(jax.vmap(_seg_max)))

    # two-level stabilizer: c_tok[i] = max(coarse row max, max over the
    # selected blocks' true scores in row i). exp never overflows, and the
    # masked-out exp arguments can no longer poison gradients with 0 * inf.
    s_for_max = jnp.where(fine_ok, s, NEG_INF)
    row_max_blk = jnp.max(s_for_max, axis=-1)  # (B,Hkv,G,m,b)
    fine_max = seg_max(
        jnp.full((B, Hkv, G, nb, b), NEG_INF, cdt), x_idx, row_max_blk
    )  # (B,Hkv,G,nb,b)
    c_tok = jnp.maximum(fine_max, c_bg[..., None])  # (B,Hkv,G,nb,b)
    c_tok = jax.lax.stop_gradient(c_tok)
    adj = jnp.exp(c_bg[..., None] - c_tok)  # (B,Hkv,G,nb,b), in (0, 1]

    c_sel = jnp.take_along_axis(
        c_tok, x_idx[..., None], axis=-2
    )  # (B,Hkv,G,m,b) per-token stabilizer for each selected block
    s = s - c_sel[..., None]
    a = jnp.where(fine_ok, jnp.exp(jnp.minimum(s, 80.0)), 0.0)  # (B,Hkv,G,m,b,b)

    o_blk = jnp.einsum("bhgmij,bhgmjd->bhgmid", a, v_sel)  # (B,Hkv,G,m,b,D)
    r_blk = jnp.sum(a, axis=-1)  # (B,Hkv,G,m,b)

    # scatter-add per query block (sequential-grid-equivalent of CUDA atomics)
    zero_o = jnp.zeros((B, Hkv, G, nb, b, D), cdt)
    zero_r = jnp.zeros((B, Hkv, G, nb, b), cdt)
    out_hr = seg(zero_o, x_idx, o_blk)  # (B,Hkv,G,nb,b,D)
    rs_hr = seg(zero_r, x_idx, r_blk)  # (B,Hkv,G,nb,b)
    return out_hr, rs_hr, adj


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    softmax_scale: Optional[float] = None,
    key_mask: Optional[jax.Array] = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Exact softmax attention oracle (GQA aware). O(n^2)."""
    B, Hq, N, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, Hkv, G, N, D).astype(compute_dtype)
    s = jnp.einsum("bhgid,bhjd->bhgij", qg, k.astype(compute_dtype)) * scale
    if causal:
        r = jnp.arange(N)
        s = jnp.where((r[:, None] >= r[None, :])[None, None, None], s, NEG_INF)
    if key_mask is not None:
        s = jnp.where(key_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgij,bhjd->bhgid", p, v.astype(compute_dtype))
    return out.reshape(B, Hq, N, D).astype(q.dtype)
