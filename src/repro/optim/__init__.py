from .adamw import AdamW, AdamWState, cosine_schedule, zero_pspec
from .compression import EFState, compress, decompress, init_ef
