"""AdamW with global-norm clipping and ZeRO-1-shardable state (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object  # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def abstract_state(self, abstract_params, mesh=None, rules=None) -> AdamWState:
        """ShapeDtypeStruct state for dry-runs (ZeRO-sharded when mesh given).

        The moment shardings COMPOSE the parameter's own sharding (TP/EP) with
        an extra data-axis shard on the largest free dim (ZeRO-1): replicating
        moments over the model axis costs |model| x the memory (§Perf K3)."""

        def one(p):
            if mesh is None:
                return jax.ShapeDtypeStruct(p.shape, jnp.float32)
            base = getattr(getattr(p, "sharding", None), "spec", None)
            return jax.ShapeDtypeStruct(
                p.shape, jnp.float32,
                sharding=jax.sharding.NamedSharding(
                    mesh, zero_pspec(p.shape, mesh, rules, base=base)),
            )

        return AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32) if mesh is None
            else jax.ShapeDtypeStruct((), jnp.int32, sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())),
            mu=jax.tree.map(one, abstract_params),
            nu=jax.tree.map(one, abstract_params),
        )

    def update(self, grads, state: AdamWState, params, lr: jax.Array):
        # global-norm clip (fp32)
        sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
        gnorm = jnp.sqrt(jax.tree.reduce(jnp.add, sq))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        c1 = 1.0 - self.b1**step.astype(jnp.float32)
        c2 = 1.0 - self.b2**step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu = self.b1 * mu + (1 - self.b1) * g
            nu = self.b2 * nu + (1 - self.b2) * g * g
            u = (mu / c1) / (jnp.sqrt(nu / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_mu, new_nu), gnorm


def zero_pspec(shape, mesh, rules: Optional[ShardingRules] = None, *, base=None):
    """ZeRO-1: shard the largest *free* divisible dim of optimizer state over
    the data axes, composed on top of the parameter's own spec (``base``)."""
    rules = rules or ShardingRules()
    groups = rules.rules.get("zero", (("data",),))
    parts = list(base) + [None] * (len(shape) - len(base)) if base is not None \
        else [None] * len(shape)
    used = set()
    for p in parts:
        if p is None:
            continue
        used.update(p if isinstance(p, tuple) else (p,))
    for group in groups:
        if not all(a in mesh.shape for a in group):
            continue
        if any(a in used for a in group):
            continue
        size = 1
        for a in group:
            size *= mesh.shape[a]
        dims = [i for i, d in enumerate(shape)
                if parts[i] is None and d % size == 0 and d >= size]
        if dims:
            dim = max(dims, key=lambda i: shape[i])
            parts[dim] = group if len(group) > 1 else group[0]
            break
    return jax.sharding.PartitionSpec(*parts)


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr
