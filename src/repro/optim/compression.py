"""Gradient compression with error feedback (distributed-optimization trick).

Microbatch gradients are accumulated in bf16 (half the accumulation memory
and, on the explicit-collective path, half the all-reduce bytes); the
quantization error is carried in a small fp32 residual ("error feedback",
Seide et al. 2014 / Karimireddy et al. 2019) so the *long-run* gradient sum
is unbiased. Enabled by ``TrainConfig.grad_compression="bf16_ef"``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: object  # fp32 pytree


def init_ef(params) -> EFState:
    return EFState(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress(grads, ef: EFState):
    """Return (bf16 grads to accumulate/reduce, new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        gq = gf.astype(jnp.bfloat16)
        return gq, gf - gq.astype(jnp.float32)

    out = jax.tree.map(one, grads, ef.residual)
    gq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return gq, EFState(res)


def decompress(grads_bf16):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads_bf16)
