from .params import (
    ParamSpec,
    abstract_params,
    cast_tree,
    count_params,
    init_params,
    param_pspecs,
    param_shardings,
)
from .registry import get_model
