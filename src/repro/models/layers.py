"""Shared neural-net layers (pure JAX, ParamSpec-declared)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec, decode_attention, self_attention
from .params import ParamSpec


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_specs(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": ParamSpec((d,), ("d_model",), init="ones"),
                "b": ParamSpec((d,), ("d_model",), init="zeros")}
    return {"w": ParamSpec((d,), ("d_model",), init="ones")}


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, H, S, Hd); positions (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (S, hd/2) or (B, S, hd/2)
    if ang.ndim == 3:  # per-batch positions (decode): insert the head axis
        ang = ang[:, None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention block (GQA, optional qkv-bias / qk-norm, MRA-switchable)
# --------------------------------------------------------------------------- #
def attn_specs(cfg: ModelConfig):
    d, Hkv, hd = cfg.d_model, cfg.kv_heads, cfg.hd
    H = cfg.padded_heads  # == num_heads unless pad_attn_heads_to is set
    p = {
        "wq": ParamSpec((d, H, hd), ("d_model", "heads", None), dtype=cfg.pdt),
        "wk": ParamSpec((d, Hkv, hd), ("d_model", "kv_heads", None), dtype=cfg.pdt),
        "wv": ParamSpec((d, Hkv, hd), ("d_model", "kv_heads", None), dtype=cfg.pdt),
        "wo": ParamSpec((H, hd, d), ("heads", None, "d_model"), dtype=cfg.pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H, hd), ("heads", None), dtype=cfg.pdt, init="zeros")
        p["bk"] = ParamSpec((Hkv, hd), ("kv_heads", None), dtype=cfg.pdt, init="zeros")
        p["bv"] = ParamSpec((Hkv, hd), ("kv_heads", None), dtype=cfg.pdt, init="zeros")
    if cfg.qk_norm:
        p["qnorm"] = ParamSpec((cfg.hd,), (None,), dtype=cfg.pdt, init="ones")
        p["knorm"] = ParamSpec((cfg.hd,), (None,), dtype=cfg.pdt, init="ones")
    return p


def head_mask(cfg: ModelConfig):
    """(padded_heads,) 1 for real heads, 0 for TP padding."""
    return (jnp.arange(cfg.padded_heads) < cfg.num_heads)


def qkv_project(x, p, cfg: ModelConfig, positions):
    """x (B,S,d) -> q (B,H,S,hd), k/v (B,Hkv,S,hd), rope applied.

    With cfg.pad_attn_heads_to set, H is the padded head count (the padded
    heads are masked at the output projection in attn_block)."""
    adt = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(adt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)[None, :, None, :]
        k = k + p["bk"].astype(adt)[None, :, None, :]
        v = v + p["bv"].astype(adt)[None, :, None, :]
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"], cfg.norm_eps)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def expand_kv_slots(k, v, cfg: ModelConfig):
    """Expand the KV head axis to cfg.kv_slots (TP sharding; weights shared)."""
    rep = cfg.kv_slots // cfg.kv_heads
    if rep == 1:
        return k, v
    return jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1)


def _tp_attn_constraint(cfg: ModelConfig, *arrays):
    """Shard (B, H, S, D) activations over (data, model) when padding is on."""
    from repro.distributed import mesh_utils

    mesh = mesh_utils.get_mesh()
    if cfg.pad_attn_heads_to <= 0 or mesh is None or "model" not in mesh.shape:
        return arrays
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = mesh_utils.dp_axes(mesh)
    out = []
    for a in arrays:
        if a.shape[1] % mesh.shape["model"] == 0:
            sh = NamedSharding(mesh, P(dp, "model", None, None))
            a = jax.lax.with_sharding_constraint(a, sh)
        out.append(a)
    return tuple(out)


def attn_block(x, p, cfg: ModelConfig, *, spec: Optional[AttentionSpec] = None,
               key_mask=None, positions=None):
    """Full-sequence attention block (training / prefill-without-cache)."""
    B, S, d = x.shape
    if positions is None:
        positions = jnp.arange(S)
    spec = spec or cfg.attn_spec
    q, k, v = qkv_project(x, p, cfg, positions)
    k, v = expand_kv_slots(k, v, cfg)
    q, k, v = _tp_attn_constraint(cfg, q, k, v)
    o = self_attention(q, k, v, spec, causal=cfg.causal, key_mask=key_mask)
    if cfg.padded_heads != cfg.num_heads:
        o = o * head_mask(cfg)[None, :, None, None].astype(o.dtype)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))


def attn_block_decode(x, p, cfg: ModelConfig, k_cache, v_cache, lengths, *,
                      spec: Optional[AttentionSpec] = None, pyramid=None):
    """One-token decode. x (B,1,d); returns (out (B,1,d), k_new, v_new).

    The KV cache stores the *real* kv_heads (no slot expansion — decode is
    memory-bound); padded query heads still work since Hq_pad % kv_heads == 0.
    """
    spec = spec or cfg.attn_spec
    positions = (lengths - 1)[:, None]  # (B,1)
    q, k_new, v_new = qkv_project(x, p, cfg, positions)
    b_idx = jnp.arange(x.shape[0])
    k_cache = k_cache.at[b_idx, :, lengths - 1].set(k_new[:, :, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, :, lengths - 1].set(v_new[:, :, 0].astype(v_cache.dtype))
    o = decode_attention(q, k_cache, v_cache, lengths, spec, pyramid=pyramid)
    if cfg.padded_heads != cfg.num_heads:
        o = o * head_mask(cfg)[None, :, None, None].astype(o.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def mlp_specs(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("d_model", "d_ff"), dtype=cfg.pdt),
            "wg": ParamSpec((d, f), ("d_model", "d_ff"), dtype=cfg.pdt),
            "wo": ParamSpec((f, d), ("d_ff", "d_model"), dtype=cfg.pdt),
        }
    return {
        "wi": ParamSpec((d, f), ("d_model", "d_ff"), dtype=cfg.pdt),
        "bi": ParamSpec((f,), ("d_ff",), dtype=cfg.pdt, init="zeros"),
        "wo": ParamSpec((f, d), ("d_ff", "d_model"), dtype=cfg.pdt),
        "bo": ParamSpec((d,), ("d_model",), dtype=cfg.pdt, init="zeros"),
    }


def mlp_block(x, p, cfg: ModelConfig):
    adt = x.dtype
    if cfg.act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(adt))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(adt))
        h = jax.nn.silu(g) * h
        return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(adt))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(adt)) + p["bi"].astype(adt)
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(adt)) + p["bo"].astype(adt)


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def embed_specs(cfg: ModelConfig):
    # vocab padded to cfg.pad_vocab_to so the table/logits shard over TP even
    # for odd vocabs (granite 49155, internvl 151655); loss masks the padding.
    V = cfg.padded_vocab
    p = {"tok": ParamSpec((V, cfg.d_model), ("vocab", "d_model"),
                          dtype=cfg.pdt, init="embed")}
    if cfg.pos == "learned":
        p["pos"] = ParamSpec((cfg.max_seq, cfg.d_model), (None, "d_model"),
                             dtype=cfg.pdt, init="embed")
    if not cfg.tie_embeddings:
        p["head"] = ParamSpec((cfg.d_model, V), ("d_model", "vocab"),
                              dtype=cfg.pdt)
    return p


def embed(tokens, p, cfg: ModelConfig, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.adt)
    if cfg.pos == "learned":
        if positions is None:
            positions = jnp.arange(tokens.shape[-1])
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cfg.adt)
    return x


def unembed(x, p, cfg: ModelConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def lm_nll(logits, targets, cfg: ModelConfig):
    """Per-position NLL with padded-vocab masking. logits (..., padded_vocab)."""
    lf = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab
        lf = jnp.where(pad_ok, lf, -1e9)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return lse - ll


def remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn
