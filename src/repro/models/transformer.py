"""Transformer model family: dense GQA LMs, MoE LMs, HuBERT encoder, VLM.

One stack implementation covers the assigned architectures:
  * dense:      qwen2-7b, llama3.2-3b, qwen3-1.7b, yi-6b (causal GQA LMs)
  * moe:        kimi-k2-1t-a32b, granite-moe-3b-a800m (MoE FFN)
  * hubert:     hubert-xlarge (bidirectional encoder; audio-frame frontend stub)
  * internvl:   internvl2-1b (vision-patch frontend stub + causal LM backbone)

The paper's technique plugs in through ``cfg.attention`` (AttentionSpec):
kind="mra2"/"mra2_s" routes every attention layer through MRA.

Batch formats (built by repro.data / launch.input_specs):
  dense/moe:  {"tokens": (B,S) i32, "targets": (B,S) i32}
  hubert:     {"frames": (B,S,Fd) f32, "mask_positions": (B,S) bool,
               "targets": (B,S) i32}
  internvl:   {"tokens": (B,S_text) i32, "patches": (B,P,Fd) f32,
               "targets": (B,S_text) i32}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import decode_attention, self_attention
from repro.core.mra_decode import PyramidState
from . import layers as L
from .moe import moe_block, moe_specs
from .params import ParamSpec


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def layer_specs(cfg: ModelConfig):
    p = {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
    }
    if cfg.family == "moe" and cfg.moe is not None:
        p["moe"] = moe_specs(cfg)
    else:
        p["mlp"] = L.mlp_specs(cfg)
    return p


def param_specs(cfg: ModelConfig):
    from .params import stack_specs

    if cfg.scan_layers:
        layers = stack_specs(layer_specs(cfg), cfg.num_layers)
    else:
        layers = [layer_specs(cfg) for _ in range(cfg.num_layers)]
    p = {
        "embed": L.embed_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "layers": layers,
    }
    if cfg.frontend == "audio_frames":
        p["frontend"] = {
            "proj": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "d_model"),
                              dtype=cfg.pdt),
            "mask_embed": ParamSpec((cfg.d_model,), ("d_model",), dtype=cfg.pdt,
                                    init="embed"),
        }
    if cfg.frontend == "vision_patches":
        p["frontend"] = {
            "proj": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "d_model"),
                              dtype=cfg.pdt),
        }
    return p


# --------------------------------------------------------------------------- #
# Forward (full sequence: training / prefill)
# --------------------------------------------------------------------------- #
def _input_embed(params, cfg: ModelConfig, batch):
    """Returns x (B, S, d) activations and target positions info."""
    if cfg.family == "hubert":
        x = jnp.einsum(
            "bsf,fd->bsd", batch["frames"].astype(cfg.adt),
            params["frontend"]["proj"].astype(cfg.adt),
        )
        mask_emb = params["frontend"]["mask_embed"].astype(cfg.adt)
        mp = batch["mask_positions"][..., None]
        x = jnp.where(mp, mask_emb[None, None, :], x)
        if cfg.pos == "learned":
            x = x + jnp.take(params["embed"]["pos"], jnp.arange(x.shape[1]),
                             axis=0).astype(cfg.adt)
        return x
    if cfg.family == "internvl":
        patches = jnp.einsum(
            "bpf,fd->bpd", batch["patches"].astype(cfg.adt),
            params["frontend"]["proj"].astype(cfg.adt),
        )
        text = L.embed(batch["tokens"], params["embed"], cfg)
        return jnp.concatenate([patches, text], axis=1)
    return L.embed(batch["tokens"], params["embed"], cfg)


def _layer_fwd(x, p, cfg: ModelConfig, key_mask):
    aux = {}
    h = L.apply_norm(x, p["ln1"], cfg)
    x = x + L.attn_block(h, p["attn"], cfg, key_mask=key_mask)
    h = L.apply_norm(x, p["ln2"], cfg)
    if "moe" in p:
        mo, aux = moe_block(h, p["moe"], cfg)
        x = x + mo
    else:
        x = x + L.mlp_block(h, p["mlp"], cfg)
    return x, aux


def forward(params, cfg: ModelConfig, batch, *, key_mask=None):
    """Full-sequence forward; returns (logits, aux_losses)."""
    x = _input_embed(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        def body(carry, lp):
            x, aux_tot = carry
            x, aux = _layer_fwd(x, lp, cfg, key_mask)
            for v in aux.values():
                aux_tot = aux_tot + v
            return (x, aux_tot), None

        body = L.remat_wrap(body, cfg)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
    else:
        body = L.remat_wrap(
            functools.partial(_layer_fwd, cfg=cfg, key_mask=key_mask), cfg
        )
        for p in params["layers"]:
            x, aux = body(x, p)
            for v in aux.values():
                aux_total = aux_total + v
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)
    return logits, aux_total


def _layers_iter(params, cfg: ModelConfig):
    """Iterate per-layer param trees regardless of stacking."""
    from .params import layer_slice

    if cfg.scan_layers:
        return [layer_slice(params["layers"], i) for i in range(cfg.num_layers)]
    return params["layers"]


def loss_fn(params, cfg: ModelConfig, batch, *, key_mask=None):
    logits, aux = forward(params, cfg, batch, key_mask=key_mask)
    targets = batch["targets"]
    if cfg.family == "internvl":
        logits = logits[:, cfg.num_patches :]
    nll = L.lm_nll(logits, targets, cfg)
    if cfg.family == "hubert":
        w = batch["mask_positions"].astype(jnp.float32)  # predict only masked
        loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    else:
        loss = jnp.mean(nll)
    metrics = {"loss": loss, "aux_loss": aux, "nll": loss}
    return loss + aux, metrics


# --------------------------------------------------------------------------- #
# Serving: KV cache, prefill, decode
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """KV cache as ParamSpecs (so the dry-run can make abstract caches).

    Per-layer entries (lists), not one stacked (L, ...) array: scatters into
    a stacked cache fuse into whole-cache updates (and XLA-CPU lowers bf16
    scatter via a full fp32 round-trip — §Perf iteration Y2); per-layer
    tensors bound the update working set to one layer.
    """
    hd, Hkv, Lx = cfg.hd, cfg.kv_heads, cfg.num_layers
    dt = cfg.adt
    quant = cfg.attention.kv_quant and cfg.attention.kind in ("mra2", "mra2_s")
    kv_dt = jnp.int8 if quant else dt
    kv_spec = ParamSpec((batch, Hkv, max_len, hd),
                        ("batch", "kv_heads", "kv_seq", None), dtype=kv_dt,
                        init="zeros")
    c = {
        "k": [kv_spec for _ in range(Lx)],
        "v": [kv_spec for _ in range(Lx)],
        "lengths": ParamSpec((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }
    if quant:
        sc_spec = ParamSpec((batch, Hkv, max_len),
                            ("batch", "kv_heads", "kv_seq"), dtype=jnp.float32,
                            init="zeros")
        c["k_scale"] = [sc_spec for _ in range(Lx)]
        c["v_scale"] = [sc_spec for _ in range(Lx)]
    if cfg.attention.kind in ("mra2", "mra2_s"):
        nb = max_len // cfg.attention.block_size
        pyr_spec = ParamSpec((batch, Hkv, nb, hd),
                             ("batch", "kv_heads", None, None),
                             dtype=jnp.float32, init="zeros")
        c["pyr_k"] = [pyr_spec for _ in range(Lx)]
        c["pyr_v"] = [pyr_spec for _ in range(Lx)]
    return c


def prefill(params, cfg: ModelConfig, batch, cache):
    """Run the full prompt, fill the cache, return (last_logits, cache)."""
    x = _input_embed(params, cfg, batch)
    B, S, d = x.shape
    positions = jnp.arange(S)
    new_cache = dict(cache)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
        ke, ve = L.expand_kv_slots(k, v, cfg)
        q, ke, ve = L._tp_attn_constraint(cfg, q, ke, ve)
        o = self_attention(q, ke, ve, cfg.attn_spec, causal=cfg.causal)
        if cfg.padded_heads != cfg.num_heads:
            o = o * L.head_mask(cfg)[None, :, None, None].astype(o.dtype)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        if "moe" in p:
            mo, _ = moe_block(h, p["moe"], cfg)
            x = x + mo
        else:
            x = x + L.mlp_block(h, p["mlp"], cfg)
        new_cache["k"] = list(new_cache["k"])
        new_cache["v"] = list(new_cache["v"])
        if "k_scale" in new_cache:  # int8 KV cache (§Perf Y3)
            from repro.core.mra_decode import quantize_kv

            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            new_cache["k_scale"] = list(new_cache["k_scale"])
            new_cache["v_scale"] = list(new_cache["v_scale"])
            new_cache["k_scale"][i] = new_cache["k_scale"][i].at[:, :, :S].set(ksc)
            new_cache["v_scale"][i] = new_cache["v_scale"][i].at[:, :, :S].set(vsc)
            new_cache["k"][i] = new_cache["k"][i].at[:, :, :S].set(kq)
            new_cache["v"][i] = new_cache["v"][i].at[:, :, :S].set(vq)
        else:
            new_cache["k"][i] = new_cache["k"][i].at[:, :, :S].set(
                k.astype(new_cache["k"][i].dtype))
            new_cache["v"][i] = new_cache["v"][i].at[:, :, :S].set(
                v.astype(new_cache["v"][i].dtype))
        if "pyr_k" in new_cache:
            bs = cfg.attention.block_size
            kb = k.reshape(B, cfg.kv_heads, S // bs, bs, cfg.hd).sum(3, dtype=jnp.float32)
            vb = v.reshape(B, cfg.kv_heads, S // bs, bs, cfg.hd).sum(3, dtype=jnp.float32)
            new_cache["pyr_k"] = list(new_cache["pyr_k"])
            new_cache["pyr_v"] = list(new_cache["pyr_v"])
            new_cache["pyr_k"][i] = new_cache["pyr_k"][i].at[:, :, : S // bs].set(kb)
            new_cache["pyr_v"][i] = new_cache["pyr_v"][i].at[:, :, : S // bs].set(vb)
    new_cache["lengths"] = jnp.full_like(cache["lengths"], S)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One decode step. tokens (B,) int32 -> (logits (B,V), cache)."""
    B = tokens.shape[0]
    lengths = cache["lengths"] + 1  # includes the new token
    x = L.embed(tokens[:, None], params["embed"], cfg)
    new_cache = dict(cache)
    b_idx = jnp.arange(B)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        positions = (lengths - 1)[:, None]
        q, k_new, v_new = L.qkv_project(h, p["attn"], cfg, positions)
        ks = vs = None
        if "k_scale" in new_cache:  # int8 KV cache (§Perf Y3)
            from repro.core.mra_decode import quantize_kv

            kq, ksc = quantize_kv(k_new[:, :, 0])
            vq, vsc = quantize_kv(v_new[:, :, 0])
            new_cache["k_scale"] = list(new_cache["k_scale"])
            new_cache["v_scale"] = list(new_cache["v_scale"])
            ks = new_cache["k_scale"][i].at[b_idx, :, lengths - 1].set(ksc)
            vs = new_cache["v_scale"][i].at[b_idx, :, lengths - 1].set(vsc)
            new_cache["k_scale"][i] = ks
            new_cache["v_scale"][i] = vs
            k_write, v_write = kq, vq
        else:
            k_write = k_new[:, :, 0].astype(new_cache["k"][i].dtype)
            v_write = v_new[:, :, 0].astype(new_cache["v"][i].dtype)
        kc = new_cache["k"][i].at[b_idx, :, lengths - 1].set(k_write)
        vc = new_cache["v"][i].at[b_idx, :, lengths - 1].set(v_write)
        new_cache["k"] = list(new_cache["k"])
        new_cache["v"] = list(new_cache["v"])
        new_cache["k"][i] = kc
        new_cache["v"][i] = vc
        pyramid = None
        if "pyr_k" in new_cache:
            bs = cfg.attention.block_size
            blk = (lengths - 1) // bs
            pk = new_cache["pyr_k"][i].at[b_idx, :, blk].add(
                k_new[:, :, 0].astype(jnp.float32)
            )
            pv = new_cache["pyr_v"][i].at[b_idx, :, blk].add(
                v_new[:, :, 0].astype(jnp.float32)
            )
            new_cache["pyr_k"] = list(new_cache["pyr_k"])
            new_cache["pyr_v"] = list(new_cache["pyr_v"])
            new_cache["pyr_k"][i] = pk
            new_cache["pyr_v"][i] = pv
            pyramid = PyramidState(pk, pv)
        o = decode_attention(q, kc, vc, lengths, cfg.attn_spec, pyramid=pyramid,
                             k_scale=ks, v_scale=vs)
        if cfg.padded_heads != cfg.num_heads:
            o = o * L.head_mask(cfg)[None, :, None, None].astype(o.dtype)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        if "moe" in p:
            mo, _ = moe_block(h, p["moe"], cfg)
            x = x + mo
        else:
            x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache["lengths"] = lengths
    return logits, new_cache
