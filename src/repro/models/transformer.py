"""Transformer model family: dense GQA LMs, MoE LMs, HuBERT encoder, VLM.

One stack implementation covers the assigned architectures:
  * dense:      qwen2-7b, llama3.2-3b, qwen3-1.7b, yi-6b (causal GQA LMs)
  * moe:        kimi-k2-1t-a32b, granite-moe-3b-a800m (MoE FFN)
  * hubert:     hubert-xlarge (bidirectional encoder; audio-frame frontend stub)
  * internvl:   internvl2-1b (vision-patch frontend stub + causal LM backbone)

The paper's technique plugs in through ``cfg.attention`` (AttentionSpec):
kind="mra2"/"mra2_s" routes every attention layer through MRA.

Batch formats (built by repro.data / launch.input_specs):
  dense/moe:  {"tokens": (B,S) i32, "targets": (B,S) i32}
  hubert:     {"frames": (B,S,Fd) f32, "mask_positions": (B,S) bool,
               "targets": (B,S) i32}
  internvl:   {"tokens": (B,S_text) i32, "patches": (B,P,Fd) f32,
               "targets": (B,S_text) i32}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hier
from repro.core.attention import chunk_attention, decode_attention, self_attention
from repro.core.mra_decode import PyramidState
from . import layers as L
from .moe import moe_block, moe_specs
from .params import ParamSpec


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def layer_specs(cfg: ModelConfig):
    p = {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
    }
    if cfg.family == "moe" and cfg.moe is not None:
        p["moe"] = moe_specs(cfg)
    else:
        p["mlp"] = L.mlp_specs(cfg)
    return p


def param_specs(cfg: ModelConfig):
    from .params import stack_specs

    if cfg.scan_layers:
        layers = stack_specs(layer_specs(cfg), cfg.num_layers)
    else:
        layers = [layer_specs(cfg) for _ in range(cfg.num_layers)]
    p = {
        "embed": L.embed_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "layers": layers,
    }
    if cfg.frontend == "audio_frames":
        p["frontend"] = {
            "proj": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "d_model"),
                              dtype=cfg.pdt),
            "mask_embed": ParamSpec((cfg.d_model,), ("d_model",), dtype=cfg.pdt,
                                    init="embed"),
        }
    if cfg.frontend == "vision_patches":
        p["frontend"] = {
            "proj": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "d_model"),
                              dtype=cfg.pdt),
        }
    return p


# --------------------------------------------------------------------------- #
# Forward (full sequence: training / prefill)
# --------------------------------------------------------------------------- #
def _input_embed(params, cfg: ModelConfig, batch):
    """Returns x (B, S, d) activations and target positions info."""
    if cfg.family == "hubert":
        x = jnp.einsum(
            "bsf,fd->bsd", batch["frames"].astype(cfg.adt),
            params["frontend"]["proj"].astype(cfg.adt),
        )
        mask_emb = params["frontend"]["mask_embed"].astype(cfg.adt)
        mp = batch["mask_positions"][..., None]
        x = jnp.where(mp, mask_emb[None, None, :], x)
        if cfg.pos == "learned":
            x = x + jnp.take(params["embed"]["pos"], jnp.arange(x.shape[1]),
                             axis=0).astype(cfg.adt)
        return x
    if cfg.family == "internvl":
        patches = jnp.einsum(
            "bpf,fd->bpd", batch["patches"].astype(cfg.adt),
            params["frontend"]["proj"].astype(cfg.adt),
        )
        text = L.embed(batch["tokens"], params["embed"], cfg)
        return jnp.concatenate([patches, text], axis=1)
    return L.embed(batch["tokens"], params["embed"], cfg)


def _layer_fwd(x, p, cfg: ModelConfig, key_mask):
    aux = {}
    h = L.apply_norm(x, p["ln1"], cfg)
    x = x + L.attn_block(h, p["attn"], cfg, key_mask=key_mask)
    h = L.apply_norm(x, p["ln2"], cfg)
    if "moe" in p:
        mo, aux = moe_block(h, p["moe"], cfg)
        x = x + mo
    else:
        x = x + L.mlp_block(h, p["mlp"], cfg)
    return x, aux


def forward(params, cfg: ModelConfig, batch, *, key_mask=None):
    """Full-sequence forward; returns (logits, aux_losses)."""
    x = _input_embed(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        def body(carry, lp):
            x, aux_tot = carry
            x, aux = _layer_fwd(x, lp, cfg, key_mask)
            for v in aux.values():
                aux_tot = aux_tot + v
            return (x, aux_tot), None

        body = L.remat_wrap(body, cfg)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
    else:
        body = L.remat_wrap(
            functools.partial(_layer_fwd, cfg=cfg, key_mask=key_mask), cfg
        )
        for p in params["layers"]:
            x, aux = body(x, p)
            for v in aux.values():
                aux_total = aux_total + v
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)
    return logits, aux_total


def _layers_iter(params, cfg: ModelConfig):
    """Iterate per-layer param trees regardless of stacking."""
    from .params import layer_slice

    if cfg.scan_layers:
        return [layer_slice(params["layers"], i) for i in range(cfg.num_layers)]
    return params["layers"]


def loss_fn(params, cfg: ModelConfig, batch, *, key_mask=None):
    logits, aux = forward(params, cfg, batch, key_mask=key_mask)
    targets = batch["targets"]
    if cfg.family == "internvl":
        logits = logits[:, cfg.num_patches :]
    nll = L.lm_nll(logits, targets, cfg)
    if cfg.family == "hubert":
        w = batch["mask_positions"].astype(jnp.float32)  # predict only masked
        loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    else:
        loss = jnp.mean(nll)
    metrics = {"loss": loss, "aux_loss": aux, "nll": loss}
    return loss + aux, metrics


# --------------------------------------------------------------------------- #
# Serving: KV cache, prefill, decode
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """KV cache as ParamSpecs (so the dry-run can make abstract caches).

    Per-layer entries (lists), not one stacked (L, ...) array: scatters into
    a stacked cache fuse into whole-cache updates (and XLA-CPU lowers bf16
    scatter via a full fp32 round-trip — §Perf iteration Y2); per-layer
    tensors bound the update working set to one layer.
    """
    hd, Hkv, Lx = cfg.hd, cfg.kv_heads, cfg.num_layers
    dt = cfg.adt
    quant = cfg.attention.kv_quant and cfg.attention.kind in ("mra2", "mra2_s")
    kv_dt = jnp.int8 if quant else dt
    kv_spec = ParamSpec((batch, Hkv, max_len, hd),
                        ("batch", "kv_heads", "kv_seq", None), dtype=kv_dt,
                        init="zeros")
    c = {
        "k": [kv_spec for _ in range(Lx)],
        "v": [kv_spec for _ in range(Lx)],
        "lengths": ParamSpec((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }
    if quant:
        sc_spec = ParamSpec((batch, Hkv, max_len),
                            ("batch", "kv_heads", "kv_seq"), dtype=jnp.float32,
                            init="zeros")
        c["k_scale"] = [sc_spec for _ in range(Lx)]
        c["v_scale"] = [sc_spec for _ in range(Lx)]
    if cfg.attention.kind in ("mra2", "mra2_s"):
        nb = max_len // cfg.attention.block_size
        pyr_spec = ParamSpec((batch, Hkv, nb, hd),
                             ("batch", "kv_heads", None, None),
                             dtype=jnp.float32, init="zeros")
        c["pyr_k"] = [pyr_spec for _ in range(Lx)]
        c["pyr_v"] = [pyr_spec for _ in range(Lx)]
        # ring page table (shared by all layers: every layer writes the same
        # positions): physical page -> logical block, -1 = never written.
        # Makes the fixed-size cache a ring over the newest ~max_len tokens —
        # decode past max_len evicts the oldest background block per slot
        # (DESIGN.md §9) instead of overflowing.
        c["page_blocks"] = ParamSpec((batch, nb), ("batch", None),
                                     dtype=jnp.int32, init="fill", scale=-1)
        if cfg.attention.levels >= 3:
            # H-level pyramid (core/hier.py, DESIGN.md §14): collapsed rings
            # over *evicted* history. Per level: int8 per-entry means (int4
            # precision via the clip range at levels >= 3) + fp32 scales per
            # layer; the owner/count tables are shared across layers exactly
            # like page_blocks (every layer evicts the same blocks). The
            # fp32 tail absorbs history past the top level. At levels == 2
            # none of these keys exist and the cache tree is byte-identical
            # to the two-level scheme.
            n = cfg.attention.hier_pages or nb
            hmean = ParamSpec((batch, Hkv, n, hd),
                              ("batch", "kv_heads", None, None),
                              dtype=jnp.int8, init="zeros")
            hscale = ParamSpec((batch, Hkv, n), ("batch", "kv_heads", None),
                               dtype=jnp.float32, init="zeros")
            for lvl in range(2, cfg.attention.levels):
                c[f"hier_k{lvl}"] = [hmean for _ in range(Lx)]
                c[f"hier_v{lvl}"] = [hmean for _ in range(Lx)]
                c[f"hier_ks{lvl}"] = [hscale for _ in range(Lx)]
                c[f"hier_vs{lvl}"] = [hscale for _ in range(Lx)]
                c[f"hier_own{lvl}"] = ParamSpec(
                    (batch, n), ("batch", None), dtype=jnp.int32,
                    init="fill", scale=-1)
                c[f"hier_cnt{lvl}"] = ParamSpec(
                    (batch, n), ("batch", None), dtype=jnp.int32,
                    init="zeros")
            tail = ParamSpec((batch, Hkv, hd), ("batch", "kv_heads", None),
                             dtype=jnp.float32, init="zeros")
            c["tail_k"] = [tail for _ in range(Lx)]
            c["tail_v"] = [tail for _ in range(Lx)]
            c["tail_cnt"] = ParamSpec((batch,), ("batch",), dtype=jnp.int32,
                                      init="zeros")
    return c


def layer_cache_kinds(cfg: ModelConfig):
    """Per-layer serving-cache kinds (serve/cache protocol, DESIGN.md §12).

    Every transformer layer holds KV state: ring-paged with pyramid block
    sums under the MRA attention kinds, plain dense KV otherwise.
    """
    kind = "paged_kv" if cfg.attention.kind in ("mra2", "mra2_s") else "kv"
    return [kind] * cfg.num_layers


def prefill(params, cfg: ModelConfig, batch, cache):
    """Run the full prompt, fill the cache, return (last_logits, cache)."""
    x = _input_embed(params, cfg, batch)
    B, S, d = x.shape
    positions = jnp.arange(S)
    new_cache = dict(cache)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
        ke, ve = L.expand_kv_slots(k, v, cfg)
        q, ke, ve = L._tp_attn_constraint(cfg, q, ke, ve)
        o = self_attention(q, ke, ve, cfg.attn_spec, causal=cfg.causal)
        if cfg.padded_heads != cfg.num_heads:
            o = o * L.head_mask(cfg)[None, :, None, None].astype(o.dtype)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        if "moe" in p:
            mo, _ = moe_block(h, p["moe"], cfg)
            x = x + mo
        else:
            x = x + L.mlp_block(h, p["mlp"], cfg)
        new_cache["k"] = list(new_cache["k"])
        new_cache["v"] = list(new_cache["v"])
        if "k_scale" in new_cache:  # int8 KV cache (§Perf Y3)
            from repro.core.mra_decode import quantize_kv

            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            new_cache["k_scale"] = list(new_cache["k_scale"])
            new_cache["v_scale"] = list(new_cache["v_scale"])
            new_cache["k_scale"][i] = new_cache["k_scale"][i].at[:, :, :S].set(ksc)
            new_cache["v_scale"][i] = new_cache["v_scale"][i].at[:, :, :S].set(vsc)
            new_cache["k"][i] = new_cache["k"][i].at[:, :, :S].set(kq)
            new_cache["v"][i] = new_cache["v"][i].at[:, :, :S].set(vq)
        else:
            new_cache["k"][i] = new_cache["k"][i].at[:, :, :S].set(
                k.astype(new_cache["k"][i].dtype))
            new_cache["v"][i] = new_cache["v"][i].at[:, :, :S].set(
                v.astype(new_cache["v"][i].dtype))
        if "pyr_k" in new_cache:
            bs = cfg.attention.block_size
            kb = k.reshape(B, cfg.kv_heads, S // bs, bs, cfg.hd).sum(3, dtype=jnp.float32)
            vb = v.reshape(B, cfg.kv_heads, S // bs, bs, cfg.hd).sum(3, dtype=jnp.float32)
            new_cache["pyr_k"] = list(new_cache["pyr_k"])
            new_cache["pyr_v"] = list(new_cache["pyr_v"])
            new_cache["pyr_k"][i] = new_cache["pyr_k"][i].at[:, :, : S // bs].set(kb)
            new_cache["pyr_v"][i] = new_cache["pyr_v"][i].at[:, :, : S // bs].set(vb)
    if "page_blocks" in new_cache:
        nbp = new_cache["page_blocks"].shape[1]
        written = jnp.arange(nbp) < S // cfg.attention.block_size
        new_cache["page_blocks"] = jnp.where(
            written[None], jnp.arange(nbp, dtype=jnp.int32)[None],
            new_cache["page_blocks"])
    new_cache["lengths"] = jnp.full_like(cache["lengths"], S)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    return logits[:, 0], new_cache


def prefill_chunk(params, cfg: ModelConfig, cache, tokens, num_valid, *,
                  all_logits=False, collect_kv=False):
    """Chunked batched prefill: C prompt tokens per slot, ragged lengths.

    The serving engine's prefill path (DESIGN.md §9): each call advances every
    prefilling slot by up to C prompt tokens in ONE jitted dispatch — the
    chunk's K/V (and pyramid block sums) are written directly into the cache
    at the slot's current offset, then the chunk's queries run MRA chunk
    attention against the updated cache. O(ceil(P/C)) dispatches per prompt
    instead of the O(P) single-token decode replays of the old engine, and a
    slot's writes never touch other slots' rows (bit-exact slot isolation).

    Speculative verification (DESIGN.md §10) reuses this dispatch unchanged:
    the chunk is [fed token, drafts] instead of prompt tokens, and it may
    start at any offset — including past the ring capacity, where a chunk
    token opening a new block recycles its page exactly like
    ``ring_pyramid_update`` (drop the evicted block's sums before adding).

    Args:
      tokens: (B, C) int32 prompt chunk per slot (padding arbitrary).
      num_valid: (B,) int32 count of real tokens in each slot's chunk;
        0 freezes the slot for this call (cache rows preserved bit-for-bit).
      all_logits: return logits at every chunk position, not just the last
        valid one — speculative verify needs the target distribution after
        each draft.
      collect_kv: also return the chunk's per-layer fp32 K/V
        ((L, B, Hkv, C, D) each) — the exact values the pyramid adds used,
        so a partial ring rewind can replay accepted-prefix contributions
        bit-for-bit even when the cache itself stores int8 pages.

    Returns:
      (logits (B, V) — or (B, C, V) when ``all_logits`` — , cache), with
      ``(chunk_k, chunk_v)`` appended when ``collect_kv``.
    """
    B, C = tokens.shape
    offsets = cache["lengths"]  # (B,)
    positions = offsets[:, None] + jnp.arange(C, dtype=offsets.dtype)  # (B,C)
    tv = jnp.arange(C) < num_valid[:, None]  # (B,C) chunk-token validity
    lengths_new = offsets + num_valid.astype(offsets.dtype)
    x = L.embed(tokens, params["embed"], cfg, positions=positions)
    new_cache = dict(cache)
    paged = "page_blocks" in cache
    bs = cfg.attention.block_size
    b_idx2 = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    tv_kv = tv[:, :, None, None]  # (B,C,1,1) masks (B,C,Hkv,hd) writes

    def scatter_tokens(arr, vals):
        """Masked per-token write: vals (B, Hkv, C, ...) -> arr (B,Hkv,S,...)."""
        widx = positions % arr.shape[2]  # distinct per lane while C <= S
        vt = jnp.swapaxes(vals, 1, 2).astype(arr.dtype)  # (B,C,Hkv,...)
        old = arr[b_idx2, :, widx]
        m = tv_kv if vt.ndim == 4 else tv[:, :, None]
        return arr.at[b_idx2, :, widx].set(jnp.where(m, vt, old))

    hplans = []
    if paged and hier.has_hier(cache):
        # H-level collapse (DESIGN.md §14), plan phase: which pages this
        # chunk recycles and where their evicted owners land in the
        # hierarchy depends only on the shared tables + positions, so the
        # carry chains run ONCE here; each layer replays the same plans on
        # its own sums inside the loop. Evictions are processed
        # oldest-block-first — the order sequential decode would use — so
        # cascades into higher levels match one-token-at-a-time collapse
        # exactly (the spec-rewind replay and the order-invariance property
        # test both pin this).
        npages = cache["page_blocks"].shape[1]
        page_c = (positions // bs) % npages
        startm = ((positions % bs) == 0) & tv
        fresh_pages = jnp.any(
            (page_c[:, :, None] == jnp.arange(npages)) & startm[:, :, None],
            axis=1)
        ht = dict(cache)
        child_cnt = jnp.full((B,), bs, jnp.int32)
        for blk_j, on_j in hier.eviction_schedule(
                cache["page_blocks"], fresh_pages, C // bs + 1):
            tupd, plan = hier.cache_collapse_tables(ht, blk_j, child_cnt, on_j)
            ht.update(tupd)
            new_cache.update(tupd)
            hplans.append((plan, blk_j % npages))

    chunk_k, chunk_v = [], []
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        q, k_new, v_new = L.qkv_project(h, p["attn"], cfg, positions)
        ks = vs = None
        if "k_scale" in new_cache:  # int8 KV cache (§Perf Y3)
            from repro.core.mra_decode import quantize_kv

            kq, ksc = quantize_kv(k_new)
            vq, vsc = quantize_kv(v_new)
            new_cache["k_scale"] = list(new_cache["k_scale"])
            new_cache["v_scale"] = list(new_cache["v_scale"])
            ks = scatter_tokens(new_cache["k_scale"][i], ksc)
            vs = scatter_tokens(new_cache["v_scale"][i], vsc)
            new_cache["k_scale"][i] = ks
            new_cache["v_scale"][i] = vs
            k_write, v_write = kq, vq
        else:
            k_write, v_write = k_new, v_new
        kc = scatter_tokens(new_cache["k"][i], k_write)
        vc = scatter_tokens(new_cache["v"][i], v_write)
        new_cache["k"] = list(new_cache["k"])
        new_cache["v"] = list(new_cache["v"])
        new_cache["k"][i] = kc
        new_cache["v"][i] = vc
        if collect_kv:
            chunk_k.append(k_new.astype(jnp.float32))
            chunk_v.append(v_new.astype(jnp.float32))
        pyramid = None
        if "pyr_k" in new_cache:
            npages = new_cache["pyr_k"][i].shape[2]
            page = (positions // bs) % npages  # (B, C)
            # dense one-hot token->page map: deterministic segment-sum (no
            # scatter-add ordering concerns), npages is small
            ind = ((page[:, :, None] == jnp.arange(npages)) & tv[:, :, None])
            ind = ind.astype(jnp.float32)
            base_k, base_v = new_cache["pyr_k"][i], new_cache["pyr_v"][i]
            if paged:
                # H-level collapse, value phase: the evicted owners' sums
                # (still intact in base_k/base_v) carry up the hierarchy
                # before the fresh-zeroing below drops them from the fine
                # pyramid. No-op list when the cache is two-level.
                for plan, pg_j in hplans:
                    hier.cache_store_layer(
                        new_cache, i,
                        hier.cache_collapse_layer(
                            new_cache, i, plan,
                            base_k[jnp.arange(B), :, pg_j],
                            base_v[jnp.arange(B), :, pg_j]))
                # ring recycle (the chunked analogue of ring_pyramid_update's
                # keep mask): a chunk token that *starts* a new block evicts
                # the page's previous owner — drop its sums before adding.
                # During prompt prefill the recycled page holds zeros (slot
                # reset), so this is exactly the pre-existing math there.
                fresh = jnp.any(
                    (ind > 0) & ((positions % bs) == 0)[:, :, None], axis=1)
                base_k = jnp.where(fresh[:, None, :, None], 0.0, base_k)
                base_v = jnp.where(fresh[:, None, :, None], 0.0, base_v)
            pk = base_k + jnp.einsum(
                "bcy,bhcd->bhyd", ind, k_new.astype(jnp.float32))
            pv = base_v + jnp.einsum(
                "bcy,bhcd->bhyd", ind, v_new.astype(jnp.float32))
            new_cache["pyr_k"] = list(new_cache["pyr_k"])
            new_cache["pyr_v"] = list(new_cache["pyr_v"])
            new_cache["pyr_k"][i] = pk
            new_cache["pyr_v"][i] = pv
            pyramid = PyramidState(pk, pv, hier.cache_upper_view(new_cache, i))
            if i == 0 and paged:  # page table is shared across layers
                touched = jnp.any(ind > 0, axis=1)  # (B, npages)
                blk_new = jnp.max(
                    jnp.where(ind > 0, (positions // bs)[:, :, None], -1),
                    axis=1).astype(jnp.int32)
                new_cache["page_blocks"] = jnp.where(
                    touched, blk_new, new_cache["page_blocks"])
        o = chunk_attention(
            q, kc, vc, lengths_new, positions, cfg.attn_spec, pyramid=pyramid,
            page_blocks=new_cache.get("page_blocks"), k_scale=ks, v_scale=vs)
        if cfg.padded_heads != cfg.num_heads:
            o = o * L.head_mask(cfg)[None, :, None, None].astype(o.dtype)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        if "moe" in p:
            mo, _ = moe_block(h, p["moe"], cfg)
            x = x + mo
        else:
            x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    if all_logits:
        logits = L.unembed(x, params["embed"], cfg)  # (B, C, V)
    else:
        last = jnp.clip(num_valid - 1, 0, C - 1)
        x_last = x[jnp.arange(B), last]  # (B, d)
        logits = L.unembed(x_last[:, None], params["embed"], cfg)[:, 0]
    new_cache["lengths"] = lengths_new
    if collect_kv:
        return logits, new_cache, (jnp.stack(chunk_k), jnp.stack(chunk_v))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, active=None):
    """One decode step. tokens (B,) int32 -> (logits (B,V), cache).

    ``active`` (B,) bool restricts the step to a subset of slots: inactive
    slots' cache rows (KV, scales, pyramid, page table, length) are preserved
    bit-for-bit so ragged continuous batching cannot perturb them, and their
    logits are garbage to be ignored by the caller. ``None`` = all active.

    With a ring-paged cache (``page_blocks`` present, DESIGN.md §9) the write
    position wraps modulo the physical cache, recycling the oldest
    background block once a slot's length exceeds the cache capacity.
    """
    B = tokens.shape[0]
    act = jnp.ones((B,), bool) if active is None else active
    lengths = cache["lengths"] + act.astype(cache["lengths"].dtype)
    x = L.embed(tokens[:, None], params["embed"], cfg)
    new_cache = dict(cache)
    b_idx = jnp.arange(B)
    paged = "page_blocks" in cache
    pos = lengths - 1  # the new token's global position (active slots)
    am2 = act[:, None]          # (B, 1)
    am3 = act[:, None, None]    # (B, 1, 1)
    hplan = page_e = None
    if paged and hier.has_hier(cache):
        # H-level collapse (DESIGN.md §14), plan phase: a token that starts
        # a new block recycles its ring page — the page's previous owner
        # carries into the hierarchy. The shared tables update once here
        # (like page_blocks); every layer replays the plan on its own sums
        # below, reading the evicted sums from its pyramid *before*
        # ring_pyramid_update zeroes them.
        bs0 = cfg.attention.block_size
        npages = cache["page_blocks"].shape[1]
        page_e = (pos // bs0) % npages
        old_owner = cache["page_blocks"][b_idx, page_e]
        evict = act & ((pos % bs0) == 0) & (old_owner >= 0)
        tupd, hplan = hier.cache_collapse_tables(
            cache, old_owner, jnp.full((B,), bs0, jnp.int32), evict)
        new_cache.update(tupd)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        q, k_new, v_new = L.qkv_project(h, p["attn"], cfg, pos[:, None])
        S_phys = new_cache["k"][i].shape[2]
        widx = pos % S_phys if paged else pos
        ks = vs = None
        if "k_scale" in new_cache:  # int8 KV cache (§Perf Y3)
            from repro.core.mra_decode import quantize_kv

            kq, ksc = quantize_kv(k_new[:, :, 0])
            vq, vsc = quantize_kv(v_new[:, :, 0])
            new_cache["k_scale"] = list(new_cache["k_scale"])
            new_cache["v_scale"] = list(new_cache["v_scale"])
            ks = new_cache["k_scale"][i]
            vs = new_cache["v_scale"][i]
            ks = ks.at[b_idx, :, widx].set(jnp.where(am2, ksc, ks[b_idx, :, widx]))
            vs = vs.at[b_idx, :, widx].set(jnp.where(am2, vsc, vs[b_idx, :, widx]))
            new_cache["k_scale"][i] = ks
            new_cache["v_scale"][i] = vs
            k_write, v_write = kq, vq
        else:
            k_write = k_new[:, :, 0].astype(new_cache["k"][i].dtype)
            v_write = v_new[:, :, 0].astype(new_cache["v"][i].dtype)
        kc = new_cache["k"][i]
        vc = new_cache["v"][i]
        kc = kc.at[b_idx, :, widx].set(jnp.where(am3, k_write, kc[b_idx, :, widx]))
        vc = vc.at[b_idx, :, widx].set(jnp.where(am3, v_write, vc[b_idx, :, widx]))
        new_cache["k"] = list(new_cache["k"])
        new_cache["v"] = list(new_cache["v"])
        new_cache["k"][i] = kc
        new_cache["v"][i] = vc
        pyramid = None
        if "pyr_k" in new_cache:
            from repro.core.mra_decode import ring_pyramid_update

            bs = cfg.attention.block_size
            pb = new_cache["page_blocks"] if paged else None
            if paged:
                if hplan is not None:  # H-level collapse, value phase (§14)
                    hier.cache_store_layer(
                        new_cache, i,
                        hier.cache_collapse_layer(
                            new_cache, i, hplan,
                            new_cache["pyr_k"][i][b_idx, :, page_e],
                            new_cache["pyr_v"][i][b_idx, :, page_e]))
                pyramid, pb = ring_pyramid_update(
                    PyramidState(new_cache["pyr_k"][i], new_cache["pyr_v"][i]),
                    pb, k_new[:, :, 0], v_new[:, :, 0], pos, bs, active=act)
                new_cache["page_blocks"] = pb
                pyramid = PyramidState(
                    pyramid.k_sum, pyramid.v_sum,
                    hier.cache_upper_view(new_cache, i))
            else:
                blk = pos // bs
                contrib_k = jnp.where(am3, k_new[:, :, 0].astype(jnp.float32), 0.0)
                contrib_v = jnp.where(am3, v_new[:, :, 0].astype(jnp.float32), 0.0)
                pyramid = PyramidState(
                    new_cache["pyr_k"][i].at[b_idx, :, blk].add(contrib_k),
                    new_cache["pyr_v"][i].at[b_idx, :, blk].add(contrib_v))
            new_cache["pyr_k"] = list(new_cache["pyr_k"])
            new_cache["pyr_v"] = list(new_cache["pyr_v"])
            new_cache["pyr_k"][i] = pyramid.k_sum
            new_cache["pyr_v"][i] = pyramid.v_sum
        o = decode_attention(q, kc, vc, lengths, cfg.attn_spec, pyramid=pyramid,
                             page_blocks=new_cache.get("page_blocks"),
                             k_scale=ks, v_scale=vs)
        if cfg.padded_heads != cfg.num_heads:
            o = o * L.head_mask(cfg)[None, :, None, None].astype(o.dtype)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        if "moe" in p:
            mo, _ = moe_block(h, p["moe"], cfg)
            x = x + mo
        else:
            x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache["lengths"] = lengths
    return logits, new_cache
