"""Mixture-of-Experts FFN with expert parallelism.

Dispatch is the sort + static-capacity-buffer formulation (MegaBlocks-style
grouping without ragged ops — every shape is static, so it jits and shards):

  1. top-k routing (per token),
  2. assignments sorted by expert id; position-in-expert via exclusive
     cumsum of counts; over-capacity assignments dropped (``mode='drop'``
     scatters — the standard TPU capacity-dropping semantics),
  3. dense per-expert matmuls on (E_local, C, d) buffers — *no* one-hot
     dispatch einsum, so HLO FLOPs equal active FLOPs (× capacity factor),
  4. combine via scatter-add weighted by the router gate.

Distribution ("replicated-psum" EP): inside a shard_map over the model axis
each device processes the full local-batch token set but only its own
E/|model| expert slice; partial outputs are psum'd. The all-to-all variant
is a §Perf iteration (EXPERIMENTS.md). Experts not divisible by the model
axis (granite's 40) fall back to per-expert d_ff tensor parallelism.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoESpec
from repro.distributed import mesh_utils
from .params import ParamSpec


def moe_specs(cfg: ModelConfig):
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_ff_expert
    return {
        "router": ParamSpec((d, E), ("d_model", None), dtype=cfg.pdt, scale=0.02),
        "wi": ParamSpec((E, d, f), ("experts", "d_model", "expert_ff"), dtype=cfg.pdt),
        "wg": ParamSpec((E, d, f), ("experts", "d_model", "expert_ff"), dtype=cfg.pdt),
        "wo": ParamSpec((E, f, d), ("experts", "expert_ff", "d_model"), dtype=cfg.pdt),
    }


def _route(x, wr, spec: MoESpec):
    """x (T, d) -> gates (T, k), idx (T, k), aux losses."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance loss + router z-loss
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = {
        "load_balance": E * jnp.sum(me * ce) * spec.aux_loss_coef,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * spec.router_z_coef,
    }
    return gates, idx, aux


def _dispatch(x, idx, *, e0: int, e_local: int, capacity: int):
    """Sort assignments, build the (e_local, capacity, d) expert buffers.

    Returns (buf, meta) where meta carries the scatter coordinates for the
    combine step."""
    T, d = x.shape
    k = idx.shape[-1]
    flat_e = idx.reshape(-1)  # (T*k,)
    local_e = flat_e - e0
    in_range = (local_e >= 0) & (local_e < e_local)
    sort_key = jnp.where(in_range, local_e, e_local)  # out-of-range sorts last
    order = jnp.argsort(sort_key)  # (T*k,)
    se = sort_key[order]
    tok = order // k
    counts = jnp.bincount(se, length=e_local + 1)[:e_local]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    starts_pad = jnp.concatenate([starts, jnp.zeros((1,), starts.dtype)])
    slot = jnp.arange(se.shape[0]) - starts_pad[se]
    keep = (se < e_local) & (slot < capacity)
    e_scatter = jnp.where(keep, se, e_local)  # dropped -> out-of-bounds
    s_scatter = jnp.where(keep, slot, capacity)
    buf = jnp.zeros((e_local, capacity, d), x.dtype)
    buf = buf.at[e_scatter, s_scatter].set(x[tok], mode="drop")
    return buf, (order, e_scatter, s_scatter, keep, tok)


def _expert_ffn(buf, wi, wg, wo):
    adt = buf.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(adt))
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(adt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(adt))


def _combine(y, meta, gates, T):
    order, e_scatter, s_scatter, keep, tok = meta
    adt = y.dtype
    gates_f = gates.reshape(-1)
    y_tok = y.at[e_scatter, s_scatter].get(mode="fill", fill_value=0)  # (T*k, d)
    y_tok = y_tok * (gates_f[order] * keep).astype(adt)[:, None]
    return jnp.zeros((T, y.shape[-1]), adt).at[tok].add(y_tok)


def _expert_compute(
    x, gates, idx, wi, wg, wo, *, e0: int, e_local: int, capacity: int
):
    """Local dense-expert compute for experts [e0, e0+e_local).

    x (T, d) fp32/bf16; returns (T, d) partial output.
    """
    buf, meta = _dispatch(x, idx, e0=e0, e_local=e_local, capacity=capacity)
    y = _expert_ffn(buf, wi, wg, wo)
    return _combine(y, meta, gates, x.shape[0])


def moe_block(x, p, cfg: ModelConfig):
    """x (B, S, d) -> (B, S, d), plus aux losses dict.

    Opens a shard_map over the model axis when a mesh with one is active.
    """
    B, S, d = x.shape
    spec = cfg.moe
    E = spec.num_experts
    mesh = mesh_utils.get_mesh()
    ep = mesh_utils.has_axis(mesh, "model") and E % mesh.shape["model"] == 0

    def local(xl, wr, wi, wg, wo, *, e0, e_local):
        T = xl.shape[0] * xl.shape[1]
        xt = xl.reshape(T, d)
        gates, idx, aux = _route(xt, wr, spec)
        cap = max(int(T * spec.top_k * spec.capacity_factor / E + 1), 4)
        out = _expert_compute(
            xt, gates, idx, wi, wg, wo, e0=e0, e_local=e_local, capacity=cap
        )
        return out.reshape(xl.shape), aux

    if mesh is None or not mesh_utils.has_axis(mesh, "model"):
        return local(x, p["router"], p["wi"], p["wg"], p["wo"], e0=0, e_local=E)

    # batch spec: shard over whatever data axes divide B (decode batches can
    # be smaller than the dp extent — fall back to replicated tokens then)
    dp = mesh_utils.dp_axes(mesh)
    import math as _math

    while dp and B % _math.prod(mesh.shape[a] for a in dp) != 0:
        dp = dp[1:]
    bspec = dp if dp else None

    def _finish(out, aux):
        out = jax.lax.psum(out, "model")
        # aux losses vary over the token (data) axes only — mean them there so
        # the result is replicated (satisfies out_specs=P()); they are already
        # invariant over "model" (routing uses replicated tokens + router).
        if dp:
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp), aux)
        return out, aux

    if not ep:
        # TP fallback (experts not divisible by |model|): shard expert d_ff.
        def tp_body(xl, wr, wi, wg, wo):
            out, aux = local(xl, wr, wi, wg, wo, e0=0, e_local=E)
            return _finish(out, aux)

        return mesh_utils.shard_map(
            tp_body,
            mesh=mesh,
            in_specs=(P(bspec, None, None), P(), P(None, None, "model"),
                      P(None, None, "model"), P(None, "model", None)),
            out_specs=(P(bspec, None, None), P()),
        )(x, p["router"], p["wi"], p["wg"], p["wo"])

    ms = mesh.shape["model"]
    e_local = E // ms

    # a2a dispatch (§Perf K2): sequence-sharded tokens, all_to_all exchange to
    # expert owners and back. Requires S divisible by the model axis (decode
    # S=1 falls back to psum).
    if cfg.moe_dispatch == "a2a" and S % ms == 0:
        def a2a_body(xl, wr, wi, wg, wo):
            # xl: (B_loc, S/ms, d) sequence shard
            T = xl.shape[0] * xl.shape[1]
            xt = xl.reshape(T, d)
            gates, idx, aux = _route(xt, wr, spec)
            cap = max(int(T * spec.top_k * spec.capacity_factor / E + 1), 4)
            buf, meta = _dispatch(xt, idx, e0=0, e_local=E, capacity=cap)
            # (E, C, d) -> exchange expert groups -> (E/ms, ms*C, d)
            recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                      tiled=True)
            y = _expert_ffn(recv, wi, wg, wo)
            back = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                                      tiled=True)  # (E, C, d)
            out = _combine(back, meta, gates, T)
            if dp:
                aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp + ("model",)), aux)
            else:
                aux = jax.tree.map(lambda a: jax.lax.pmean(a, ("model",)), aux)
            return out.reshape(xl.shape), aux

        return mesh_utils.shard_map(
            a2a_body,
            mesh=mesh,
            in_specs=(P(bspec, "model", None), P(), P("model", None, None),
                      P("model", None, None), P("model", None, None)),
            out_specs=(P(bspec, "model", None), P()),
        )(x, p["router"], p["wi"], p["wg"], p["wo"])

    def ep_body(xl, wr, wi, wg, wo):
        # xl: local batch, replicated over model; wi/wg/wo: this shard's experts
        shard = jax.lax.axis_index("model")
        e0 = shard * e_local
        out, aux = local(xl, wr, wi, wg, wo, e0=e0, e_local=e_local)
        return _finish(out, aux)

    return mesh_utils.shard_map(
        ep_body,
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
