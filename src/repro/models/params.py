"""ParamSpec: declarative parameters with logical sharding axes.

Models declare a pytree of ``ParamSpec`` (shape, logical axes, initializer).
From that single declaration the framework derives:
  * materialized parameters      (``init_params``   — smoke tests/training)
  * abstract parameters          (``abstract_params`` — dry-runs: ShapeDtypeStruct
    with a NamedSharding attached, zero bytes allocated)
  * sharding trees               (``param_shardings`` — pjit in/out_shardings)
  * parameter counts             (``count_params``)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, named_sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | fill
    scale: Optional[float] = None  # stddev; default fan-in (fill: the value)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "fill":
        return jnp.full(spec.shape, spec.scale if spec.scale is not None else 0,
                        spec.dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    # truncated-normal fan-in init
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape) * std).astype(spec.dtype)


def init_params(spec_tree, rng: jax.Array):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree, mesh=None, rules: Optional[ShardingRules] = None):
    """ShapeDtypeStruct tree (with shardings when a mesh is given) — no allocation."""

    def one(s: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=named_sharding(s.shape, s.axes, mesh, rules)
        )

    return jax.tree.map(one, spec_tree, is_leaf=_is_spec)


def param_shardings(spec_tree, mesh, rules: Optional[ShardingRules] = None):
    return jax.tree.map(
        lambda s: named_sharding(s.shape, s.axes, mesh, rules), spec_tree, is_leaf=_is_spec
    )


def param_pspecs(spec_tree, mesh, rules: Optional[ShardingRules] = None):
    from repro.distributed.sharding import logical_to_pspec

    return jax.tree.map(
        lambda s: logical_to_pspec(s.shape, s.axes, mesh, rules),
        spec_tree,
        is_leaf=_is_spec,
    )


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def stack_specs(spec_tree, n: int):
    """Prepend a stacked (layer) dimension to every ParamSpec in the tree.

    Used by ``cfg.scan_layers``: all layers' parameters live in single stacked
    arrays scanned by ``lax.scan`` — bounded live memory (one layer's
    transients) on any scheduler, and O(1) compile size in depth.
    """

    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, (None,) + s.axes, dtype=s.dtype,
                         init=s.init, scale=s.scale)

    return jax.tree.map(one, spec_tree, is_leaf=_is_spec)


def layer_slice(stacked, i: int):
    """Static slice of layer ``i`` from a stacked param tree."""
    return jax.tree.map(lambda a: a[i], stacked)


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, params
    )
