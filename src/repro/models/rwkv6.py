"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent decay.

Per-layer recurrence (per head, state S in R^{dh x dh}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(w0 + lora(x~_t))) a *data-dependent* per-channel decay.

Training/prefill uses the chunked-parallel form (GLA-style): intra-chunk
contributions are C x C matmuls with cumulative-decay weightings; inter-chunk
state propagation is a ``jax.lax.associative_scan`` over (decay, update)
pairs — log-depth, no ``while`` loop, so XLA cost analysis sees the true
FLOPs (DESIGN.md §6). A naive ``lax.scan`` reference path validates the
chunked math in tests.

MRA is *inapplicable* here (no attention matrix) — DESIGN.md §5.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from .params import ParamSpec

def _decay_clamp(chunk: int) -> float:
    """Per-step log-decay floor so the factored chunk form stays in fp32 range.

    The chunked intra-block weights are computed as
    ``(r * exp(Lprev)) @ (k * exp(-Lc))^T`` — exact iff every cumulative
    exponent |Lc| <= ~85 (fp32 exp range). Clamping each step's log decay at
    -kappa with kappa = 80/chunk guarantees that while changing semantics
    only where a channel would forget >e^-kappa of its state in ONE step
    (contributions below ~1e-35 — invisible in fp32 anyway).
    """
    return min(5.0, 80.0 / chunk)


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def layer_specs(cfg: ModelConfig):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    lora = cfg.decay_lora
    f = cfg.d_ff
    pdt = cfg.pdt
    tm = {
        "mu": ParamSpec((5, d), (None, "d_model"), dtype=pdt, init="embed"),
        "w0": ParamSpec((d,), ("d_model",), dtype=pdt, init="embed"),
        "wA": ParamSpec((d, lora), ("d_model", None), dtype=pdt, scale=0.01),
        "wB": ParamSpec((lora, d), (None, "d_model"), dtype=pdt, scale=0.01),
        "wr": ParamSpec((d, H, dh), ("d_model", "heads", None), dtype=pdt),
        "wk": ParamSpec((d, H, dh), ("d_model", "heads", None), dtype=pdt),
        "wv": ParamSpec((d, H, dh), ("d_model", "heads", None), dtype=pdt),
        "wg": ParamSpec((d, H, dh), ("d_model", "heads", None), dtype=pdt),
        "u": ParamSpec((H, dh), ("heads", None), dtype=pdt, init="embed"),
        "wo": ParamSpec((H, dh, d), ("heads", None, "d_model"), dtype=pdt),
        "gn_w": ParamSpec((H, dh), ("heads", None), dtype=pdt, init="ones"),
        "gn_b": ParamSpec((H, dh), ("heads", None), dtype=pdt, init="zeros"),
    }
    cm = {
        "mu_k": ParamSpec((d,), ("d_model",), dtype=pdt, init="embed"),
        "mu_r": ParamSpec((d,), ("d_model",), dtype=pdt, init="embed"),
        "wk": ParamSpec((d, f), ("d_model", "d_ff"), dtype=pdt),
        "wv": ParamSpec((f, d), ("d_ff", "d_model"), dtype=pdt),
        "wr": ParamSpec((d, d), ("d_model", None), dtype=pdt),
    }
    return {"ln1": L.norm_specs(cfg), "tm": tm, "ln2": L.norm_specs(cfg), "cm": cm}


def param_specs(cfg: ModelConfig):
    from .params import stack_specs

    if cfg.scan_layers:
        layers = stack_specs(layer_specs(cfg), cfg.num_layers)
    else:
        layers = [layer_specs(cfg) for _ in range(cfg.num_layers)]
    return {
        "embed": L.embed_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "layers": layers,
    }


def _layers_iter(params, cfg: ModelConfig):
    from .params import layer_slice

    if cfg.scan_layers:
        return [layer_slice(params["layers"], i) for i in range(cfg.num_layers)]
    return params["layers"]


# --------------------------------------------------------------------------- #
# Time mixing
# --------------------------------------------------------------------------- #
def _shift(x, x_prev=None):
    """Previous-token values. x (B,T,d); ``x_prev`` (B,d) is the stream's
    token before this window (zeros when the stream starts at t=0)."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _tm_inputs(x, p, cfg, x_prev=None):
    """Compute r,k,v,g (B,H,T,dh) and log-decay lw (B,H,T,dh)."""
    adt = x.dtype
    xs = _shift(x, x_prev)
    mu = p["mu"].astype(adt)  # (5, d)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))
    r = jnp.einsum("btd,dhk->bhtk", xr, p["wr"].astype(adt))
    k = jnp.einsum("btd,dhk->bhtk", xk, p["wk"].astype(adt))
    v = jnp.einsum("btd,dhk->bhtk", xv, p["wv"].astype(adt))
    g = jax.nn.silu(jnp.einsum("btd,dhk->bhtk", xg, p["wg"].astype(adt)))
    dw = jnp.einsum(
        "btl,ld->btd", jnp.tanh(jnp.einsum("btd,dl->btl", xw, p["wA"].astype(adt))),
        p["wB"].astype(adt),
    )
    H, dh = p["u"].shape
    wlog = -jnp.exp(
        (p["w0"].astype(jnp.float32) + dw.astype(jnp.float32))
        .reshape(x.shape[0], x.shape[1], H, dh)
        .transpose(0, 2, 1, 3)
    )  # (B,H,T,dh), strictly negative
    wlog = jnp.maximum(wlog, -_decay_clamp(cfg.rwkv_chunk))
    return (r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            g, wlog)


def wkv_chunked(r, k, v, lw, u, chunk: int, initial_state=None,
                return_state=False):
    """Chunked-parallel WKV. r/k/v/lw (B,H,T,dh); u (H,dh) -> y (B,H,T,dh).

    ``initial_state`` (B,H,dh,dh) carries S from a previous window so the
    serving engine can prefill a prompt chunk-by-chunk (the chunk_rwkv6
    dual-mode design); ``return_state`` additionally returns the post-window
    state S_T. Lanes with lw == 0 and k == 0 leave the state untouched, so
    ragged windows mask by zeroing those inputs past each lane's length.
    """
    B, H, T, dh = r.shape
    C = chunk
    assert T % C == 0, (T, C)
    nC = T // C
    rc, kc, vc, lwc = (a.reshape(B, H, nC, C, dh) for a in (r, k, v, lw))

    Lc = jnp.cumsum(lwc, axis=3)  # (B,H,nC,C,dh) cumulative log decay incl. step t
    Ltot = Lc[:, :, :, -1]  # (B,H,nC,dh)
    Lprev = Lc - lwc  # cumulative decay *before* step t

    # inter-chunk state: S_c = diag(exp(Ltot_c)) S_{c-1} + M_c
    kd = kc * jnp.exp(Ltot[:, :, :, None, :] - Lc)
    M = jnp.einsum("bhcti,bhctj->bhcij", kd, vc)  # (B,H,nC,dh,dh)
    D = jnp.exp(Ltot)

    def combine(a, b):
        Da, Ma = a
        Db, Mb = b
        return Da * Db, Db[..., :, None] * Ma + Mb

    Ds, Ms = jax.lax.associative_scan(combine, (D, M), axis=2)
    # state *before* each chunk
    S_prev = jnp.concatenate(
        [jnp.zeros_like(Ms[:, :, :1]), Ms[:, :, :-1]], axis=2
    )
    if initial_state is not None:
        S0 = initial_state.astype(jnp.float32)
        # decay accumulated before each chunk applies to the carried state
        D_before = jnp.concatenate(
            [jnp.ones_like(Ds[:, :, :1]), Ds[:, :, :-1]], axis=2)
        S_prev = S_prev + D_before[..., :, None] * S0[:, :, None]

    # intra-chunk: A[t,s] = r_t . exp(Lprev_t - Lc_s) k_s  (s < t), diag u bonus
    # exponents bounded by the per-step decay clamp (see _decay_clamp)
    rq = rc * jnp.exp(Lprev)
    ki = kc * jnp.exp(-Lc)
    A = jnp.einsum("bhcti,bhcsi->bhcts", rq, ki)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    diag = jnp.einsum("bhcti,hi,bhcti->bhct", rc, u.astype(jnp.float32), kc)
    y = jnp.einsum("bhcts,bhcsj->bhctj", A, vc) + diag[..., None] * vc
    y = y + jnp.einsum("bhcti,bhcij->bhctj", rq, S_prev)
    y = y.reshape(B, H, T, dh)
    if not return_state:
        return y
    S_T = Ms[:, :, -1]
    if initial_state is not None:
        S_T = S_T + Ds[:, :, -1][..., :, None] * S0
    return y, S_T


def wkv_scan(r, k, v, lw, u):
    """Naive sequential reference (lax.scan over time)."""
    B, H, T, dh = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,dh)
        a = kt[..., :, None] * vt[..., None, :]  # (B,H,dh,dh)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u.astype(jnp.float32)[None, :, :, None] * a)
        S = jnp.exp(wt)[..., :, None] * S + a
        return S, y

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r, k, v, lw))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3)


def _group_norm(y, w, b, eps):
    """Per-head normalization. y (B,H,T,dh)."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    return yn * w.astype(jnp.float32)[None, :, None, :] + b.astype(jnp.float32)[None, :, None, :]


def time_mix(x, p, cfg: ModelConfig, *, use_scan: bool = False):
    r, k, v, g, lw = _tm_inputs(x, p, cfg)
    if use_scan:
        y = wkv_scan(r, k, v, lw, p["u"])
    else:
        y = wkv_chunked(r, k, v, lw, p["u"], cfg.rwkv_chunk)
    y = _group_norm(y, p["gn_w"], p["gn_b"], cfg.norm_eps) * g.astype(jnp.float32)
    return jnp.einsum("bhtk,hkd->btd", y.astype(x.dtype), p["wo"].astype(x.dtype))


def channel_mix(x, p, cfg: ModelConfig, x_prev=None):
    adt = x.dtype
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"].astype(adt)
    xr = x + (xs - x) * p["mu_r"].astype(adt)
    k = jnp.einsum("btd,df->btf", xk, p["wk"].astype(adt))
    k = jnp.square(jax.nn.relu(k))
    out = jnp.einsum("btf,fd->btd", k, p["wv"].astype(adt))
    return jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(adt))) * out


# --------------------------------------------------------------------------- #
# Model
# --------------------------------------------------------------------------- #
def forward(params, cfg: ModelConfig, batch, *, use_scan: bool = False, key_mask=None):
    x = L.embed(batch["tokens"], params["embed"], cfg)

    def body(x, p):
        x = x + time_mix(L.apply_norm(x, p["ln1"], cfg), p["tm"], cfg, use_scan=use_scan)
        x = x + channel_mix(L.apply_norm(x, p["ln2"], cfg), p["cm"], cfg)
        return x, {}

    body = L.remat_wrap(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
    else:
        for p in params["layers"]:
            x, _ = body(x, p)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return L.unembed(x, params["embed"], cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch, *, key_mask=None):
    logits, aux = forward(params, cfg, batch)
    loss = jnp.mean(L.lm_nll(logits, batch["targets"], cfg))
    return loss, {"loss": loss, "nll": loss}


# --------------------------------------------------------------------------- #
# Serving: recurrent state instead of a KV cache
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    Lx = cfg.num_layers
    return {
        "state": ParamSpec((Lx, batch, H, dh, dh),
                           (None, "batch", "heads", None, None),
                           dtype=jnp.float32, init="zeros"),
        "tm_x": ParamSpec((Lx, batch, d), (None, "batch", "d_model"),
                          dtype=cfg.adt, init="zeros"),
        "cm_x": ParamSpec((Lx, batch, d), (None, "batch", "d_model"),
                          dtype=cfg.adt, init="zeros"),
        "lengths": ParamSpec((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, active=None):
    """One recurrent step. tokens (B,) -> (logits, cache).

    ``active`` (B,) bool restricts the step to a subset of slots: inactive
    slots' state rows (wkv state, token-shift carries, length) are preserved
    bit-for-bit so ragged continuous batching cannot perturb them, and their
    logits are garbage to be ignored by the caller. ``None`` = all active.
    """
    B = tokens.shape[0]
    act = jnp.ones((B,), bool) if active is None else active.astype(bool)
    x = L.embed(tokens[:, None], params["embed"], cfg)[:, 0]  # (B, d)
    new_cache = dict(cache)
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    for i, p in enumerate(_layers_iter(params, cfg)):
        # --- time mix (single step) ---
        h = L.apply_norm(x[:, None], p["ln1"], cfg)[:, 0]
        xs = cache["tm_x"][i].astype(h.dtype)
        mu = p["tm"]["mu"].astype(h.dtype)
        xr, xk, xv, xw, xg = (h + (xs - h) * mu[j] for j in range(5))
        r = jnp.einsum("bd,dhk->bhk", xr, p["tm"]["wr"].astype(h.dtype)).astype(jnp.float32)
        k = jnp.einsum("bd,dhk->bhk", xk, p["tm"]["wk"].astype(h.dtype)).astype(jnp.float32)
        v = jnp.einsum("bd,dhk->bhk", xv, p["tm"]["wv"].astype(h.dtype)).astype(jnp.float32)
        g = jax.nn.silu(jnp.einsum("bd,dhk->bhk", xg, p["tm"]["wg"].astype(h.dtype)))
        dw = jnp.einsum(
            "bl,ld->bd", jnp.tanh(jnp.einsum("bd,dl->bl", xw, p["tm"]["wA"].astype(h.dtype))),
            p["tm"]["wB"].astype(h.dtype),
        )
        # same per-step log-decay floor as the chunked prefill form, so a
        # decode continuation stays consistent with chunk-prefilled state
        w = jnp.exp(jnp.maximum(
            -jnp.exp((p["tm"]["w0"].astype(jnp.float32)
                      + dw.astype(jnp.float32)).reshape(B, H, dh)),
            -_decay_clamp(cfg.rwkv_chunk)))
        S = cache["state"][i]  # (B,H,dh,dh)
        a = k[..., :, None] * v[..., None, :]
        u = p["tm"]["u"].astype(jnp.float32)
        y = jnp.einsum("bhi,bhij->bhj", r, S + u[None, :, :, None] * a)
        S = jnp.where(act[:, None, None, None], w[..., :, None] * S + a, S)
        new_cache["state"] = new_cache["state"].at[i].set(S)
        new_cache["tm_x"] = new_cache["tm_x"].at[i].set(
            jnp.where(act[:, None], h.astype(cache["tm_x"].dtype),
                      cache["tm_x"][i]))
        y = _group_norm(y[:, :, None], p["tm"]["gn_w"], p["tm"]["gn_b"], cfg.norm_eps)[:, :, 0]
        y = y * g.astype(jnp.float32)
        x = x + jnp.einsum("bhk,hkd->bd", y.astype(x.dtype), p["tm"]["wo"].astype(x.dtype))
        # --- channel mix (single step) ---
        h = L.apply_norm(x[:, None], p["ln2"], cfg)[:, 0]
        xs = cache["cm_x"][i].astype(h.dtype)
        xk2 = h + (xs - h) * p["cm"]["mu_k"].astype(h.dtype)
        xr2 = h + (xs - h) * p["cm"]["mu_r"].astype(h.dtype)
        kk = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk2, p["cm"]["wk"].astype(h.dtype))))
        out = jnp.einsum("bf,fd->bd", kk, p["cm"]["wv"].astype(h.dtype))
        x = x + jax.nn.sigmoid(
            jnp.einsum("bd,de->be", xr2, p["cm"]["wr"].astype(h.dtype))
        ) * out
        new_cache["cm_x"] = new_cache["cm_x"].at[i].set(
            jnp.where(act[:, None], h.astype(cache["cm_x"].dtype),
                      cache["cm_x"][i]))
    x = L.apply_norm(x[:, None], params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache["lengths"] = cache["lengths"] + act.astype(cache["lengths"].dtype)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, cache):
    """Prefill: run chunked forward and emit the final recurrent state."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(tokens, params["embed"], cfg)
    new_cache = dict(cache)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        r, k, v, g, lw = _tm_inputs(h, p["tm"], cfg)
        y = wkv_chunked(r, k, v, lw, p["tm"]["u"], cfg.rwkv_chunk)
        # final state for decoding: S = sum_s diag(exp(L_total - L_s)) k_s^T v_s
        Lc = jnp.cumsum(lw, axis=2)
        Ltot = Lc[:, :, -1:]
        kd = k * jnp.exp(jnp.clip(Ltot - Lc, -85.0, 0.0))
        state = jnp.einsum("bhti,bhtj->bhij", kd, v)
        new_cache["state"] = new_cache["state"].at[i].set(state)
        new_cache["tm_x"] = new_cache["tm_x"].at[i].set(h[:, -1].astype(cache["tm_x"].dtype))
        y = _group_norm(y, p["tm"]["gn_w"], p["tm"]["gn_b"], cfg.norm_eps)
        y = y * g.astype(jnp.float32)
        x = x + jnp.einsum("bhtk,hkd->btd", y.astype(x.dtype), p["tm"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + channel_mix(h, p["cm"], cfg)
        new_cache["cm_x"] = new_cache["cm_x"].at[i].set(h[:, -1].astype(cache["cm_x"].dtype))
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    new_cache["lengths"] = jnp.full_like(cache["lengths"], S)
    return logits[:, 0], new_cache


def layer_cache_kinds(cfg: ModelConfig):
    """Per-layer serving-cache kinds (serve/cache protocol, DESIGN.md §12)."""
    return ["wkv"] * cfg.num_layers


def prefill_chunk(params, cfg: ModelConfig, cache, tokens, num_valid, *,
                  all_logits=False, collect_kv=False):
    """Chunked batched prefill: C prompt tokens per slot, ragged lengths.

    The serving engine's prefill path for the recurrent family: one jitted
    dispatch advances every prefilling slot's wkv state by up to C prompt
    tokens through the chunk-parallel ``wkv_chunked`` (state carried in via
    ``initial_state`` — the chunk_rwkv6 dual-mode design), instead of C
    token-by-token decode replays. Ragged lanes (position >= num_valid)
    contribute decay exp(0) = 1 and k = 0, so a lane's state past its length
    — and every lane of a slot with num_valid == 0 — is preserved
    bit-for-bit (explicit ``where`` guards on all writes).

    Returns (logits, cache): logits at each slot's last valid position, or
    (B, C, V) for every chunk position with ``all_logits``. ``collect_kv``
    is a paged-cache feature (speculative verify) and raises here.
    """
    if collect_kv:
        raise NotImplementedError(
            "recurrent state has no K/V stream to collect; speculative "
            "verify needs the ring-paged cache (DESIGN.md §12)")
    B, C = tokens.shape
    rc = cfg.rwkv_chunk
    Cp = -(-C // rc) * rc  # wkv_chunked needs a whole number of chunks
    if Cp != C:
        tokens = jnp.pad(tokens, ((0, 0), (0, Cp - C)))
    nv = num_valid.astype(jnp.int32)
    tv = jnp.arange(Cp) < nv[:, None]  # (B, Cp) lane validity
    last = jnp.clip(nv - 1, 0, Cp - 1)
    gate = nv > 0
    g2, g4 = gate[:, None], gate[:, None, None, None]
    x = L.embed(tokens, params["embed"], cfg)
    new_cache = dict(cache)
    for i, p in enumerate(_layers_iter(params, cfg)):
        h = L.apply_norm(x, p["ln1"], cfg)
        # token shift crosses the chunk boundary through the carried tm_x
        r, k, v, g, lw = _tm_inputs(h, p["tm"], cfg,
                                    x_prev=cache["tm_x"][i])
        m4 = tv[:, None, :, None]
        lw = jnp.where(m4, lw, 0.0)
        k = jnp.where(m4, k, 0.0)
        v = jnp.where(m4, v, 0.0)
        y, S_T = wkv_chunked(r, k, v, lw, p["tm"]["u"], rc,
                             initial_state=cache["state"][i],
                             return_state=True)
        new_cache["state"] = new_cache["state"].at[i].set(
            jnp.where(g4, S_T, cache["state"][i]))
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        new_cache["tm_x"] = new_cache["tm_x"].at[i].set(
            jnp.where(g2, h_last.astype(cache["tm_x"].dtype),
                      cache["tm_x"][i]))
        y = _group_norm(y, p["tm"]["gn_w"], p["tm"]["gn_b"], cfg.norm_eps)
        y = y * g.astype(jnp.float32)
        x = x + jnp.einsum("bhtk,hkd->btd", y.astype(x.dtype),
                           p["tm"]["wo"].astype(x.dtype))
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + channel_mix(h, p["cm"], cfg, x_prev=cache["cm_x"][i])
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        new_cache["cm_x"] = new_cache["cm_x"].at[i].set(
            jnp.where(g2, h_last.astype(cache["cm_x"].dtype),
                      cache["cm_x"][i]))
    x = L.apply_norm(x, params["ln_f"], cfg)
    new_cache["lengths"] = cache["lengths"] + nv
    if all_logits:
        return L.unembed(x[:, :C], params["embed"], cfg), new_cache
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)
    return L.unembed(xl, params["embed"], cfg)[:, 0], new_cache
