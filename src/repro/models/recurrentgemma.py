"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Temporal blocks repeat the pattern (rglru, rglru, local): two gated-linear-
recurrence blocks per local-attention block. The RG-LRU diagonal recurrence

    a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is evaluated with ``jax.lax.associative_scan`` (log-depth, no while loop —
accurate XLA cost analysis, DESIGN.md §6). Local attention is MQA
(kv_heads=1) over a sliding window; the window cache is a ring buffer so
``long_500k`` decoding needs O(window) memory — the arch's selling point.

MRA applies to the local-attention layers only (DESIGN.md §5): set
``cfg.attention.kind="mra2"`` to route them through the paper's scheme.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec, self_attention
from . import layers as L
from .params import ParamSpec

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


def _pattern(cfg: ModelConfig):
    pat = cfg.block_pattern or ("rglru", "rglru", "local")
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def _rglru_specs(cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    pdt = cfg.pdt
    # The lru_width axis is deliberately REPLICATED (no "d_ff"): the RG-LRU
    # recurrence is elementwise and sequential, so TP over w buys nothing on
    # the hot path but forces psum'd partial contractions (wa/wi/wo) whose
    # reassociated rounding drifts from single-device — breaking the
    # bit-exact DP x TP serving parity the engine pins (DESIGN.md §12).
    # Batch-only sharding keeps every LRU contraction local and exact.
    return {
        "wx": ParamSpec((d, w), ("d_model", None), dtype=pdt),
        "wy": ParamSpec((d, w), ("d_model", None), dtype=pdt),
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, None), dtype=pdt, scale=0.1),
        "conv_b": ParamSpec((w,), (None,), dtype=pdt, init="zeros"),
        "wa": ParamSpec((w, w), (None, None), dtype=pdt, scale=0.01),
        "ba": ParamSpec((w,), (None,), dtype=pdt, init="zeros"),
        "wi": ParamSpec((w, w), (None, None), dtype=pdt, scale=0.01),
        "bi": ParamSpec((w,), (None,), dtype=pdt, init="zeros"),
        "lam": ParamSpec((w,), (None,), dtype=pdt, init="embed", scale=0.5),
        "wo": ParamSpec((w, d), (None, "d_model"), dtype=pdt),
    }


def layer_specs(cfg: ModelConfig, kind: str):
    p = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg)}
    if kind == "local":
        p["attn"] = L.attn_specs(cfg)
    else:
        p["rglru"] = _rglru_specs(cfg)
    p["mlp"] = L.mlp_specs(cfg)
    return p


def param_specs(cfg: ModelConfig):
    kinds = _pattern(cfg)
    if cfg.scan_layers:
        from .params import stack_specs

        pat = cfg.block_pattern or ("rglru", "rglru", "local")
        n_groups = cfg.num_layers // len(pat)
        tail = kinds[n_groups * len(pat) :]
        return {
            "embed": L.embed_specs(cfg),
            "ln_f": L.norm_specs(cfg),
            "groups": stack_specs([layer_specs(cfg, k) for k in pat], n_groups),
            "tail": [layer_specs(cfg, k) for k in tail],
        }
    return {
        "embed": L.embed_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "layers": [layer_specs(cfg, k) for k in kinds],
    }


def _layers_iter(params, cfg: ModelConfig):
    """(kind, layer-params) pairs regardless of stacking."""
    kinds = _pattern(cfg)
    if not cfg.scan_layers:
        return list(zip(kinds, params["layers"]))
    from .params import layer_slice

    pat = cfg.block_pattern or ("rglru", "rglru", "local")
    n_groups = cfg.num_layers // len(pat)
    out = []
    for i in range(n_groups):
        grp = layer_slice(params["groups"], i)
        for j, kind in enumerate(pat):
            out.append((kind, grp[j]))
    for kind, p in zip(kinds[n_groups * len(pat) :], params["tail"]):
        out.append((kind, p))
    return out


# --------------------------------------------------------------------------- #
# RG-LRU block
# --------------------------------------------------------------------------- #
def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B,T,W); w (K,W). state (B,K-1,W) or None."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype)


def _rglru_scan(a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan over T. a/bx (B,T,W)."""

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, av * bu + bv

    return jax.lax.associative_scan(combine, (a, bx), axis=1)


def _decay(lam, gate):
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * gate
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a): use expm1 for stability near a=1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, mult


def rglru_block(x, p, cfg: ModelConfig):
    """x (B,T,d) -> (B,T,d)."""
    adt = x.dtype
    u = jnp.einsum("btd,dw->btw", x, p["wx"].astype(adt))
    y = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["wy"].astype(adt)))
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"].astype(jnp.float32))
    a, mult = _decay(p["lam"], r)
    _, h = _rglru_scan(a, mult * (i * uf))
    out = (h.astype(adt) * y)
    return jnp.einsum("btw,wd->btd", out, p["wo"].astype(adt))


# --------------------------------------------------------------------------- #
# Full-sequence forward
# --------------------------------------------------------------------------- #
def _local_spec(cfg: ModelConfig) -> AttentionSpec:
    # attn_spec (not attention): honor the model-level kernel routing
    if cfg.attention.kind in ("mra2", "mra2_s"):
        return cfg.attn_spec
    import dataclasses

    return dataclasses.replace(cfg.attn_spec, kind="local",
                               local_window=cfg.local_window)


def forward(params, cfg: ModelConfig, batch, *, key_mask=None):
    x = L.embed(batch["tokens"], params["embed"], cfg)

    def body(x, p, kind):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            x = x + L.attn_block(h, p["attn"], cfg, spec=_local_spec(cfg),
                                 key_mask=key_mask)
        else:
            x = x + rglru_block(h, p["rglru"], cfg)
        h = L.apply_norm(x, p["ln2"], cfg)
        return x + L.mlp_block(h, p["mlp"], cfg)

    if cfg.scan_layers:
        pat = cfg.block_pattern or ("rglru", "rglru", "local")

        def group_body(x, grp):
            for j, kind in enumerate(pat):
                x = body(x, grp[j], kind)
            return x, None

        x, _ = jax.lax.scan(L.remat_wrap(group_body, cfg), x, params["groups"])
        n_groups = cfg.num_layers // len(pat)
        kinds = _pattern(cfg)[n_groups * len(pat) :]
        for p, kind in zip(params["tail"], kinds):
            fn = L.remat_wrap(functools.partial(body, kind=kind), cfg)
            x = fn(x, p)
    else:
        for kind, p in _layers_iter(params, cfg):
            fn = L.remat_wrap(functools.partial(body, kind=kind), cfg)
            x = fn(x, p)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return L.unembed(x, params["embed"], cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch, *, key_mask=None):
    logits, _ = forward(params, cfg, batch)
    loss = jnp.mean(L.lm_nll(logits, batch["targets"], cfg))
    return loss, {"loss": loss, "nll": loss}


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    kinds = _pattern(cfg)
    n_attn = sum(1 for k in kinds if k == "local")
    n_rec = len(kinds) - n_attn
    w = cfg.lru_width or cfg.d_model
    W = min(cfg.local_window, max_len)
    return {
        "k": ParamSpec((n_attn, batch, cfg.kv_heads, W, cfg.hd),
                       (None, "batch", None, None, None), dtype=cfg.adt, init="zeros"),
        "v": ParamSpec((n_attn, batch, cfg.kv_heads, W, cfg.hd),
                       (None, "batch", None, None, None), dtype=cfg.adt, init="zeros"),
        # -1 = empty ring entry; zeros would alias an unwritten entry with a
        # real position-0 key of all-zero K/V (visible as spurious attention
        # mass on fresh slots)
        "kv_pos": ParamSpec((n_attn, batch, W), (None, "batch", None),
                            dtype=jnp.int32, init="fill", scale=-1),
        # batch-only, matching _rglru_specs: a w-sharded fp32 state would
        # re-introduce the psum drift the replicated LRU weights avoid
        "h": ParamSpec((n_rec, batch, w), (None, "batch", None),
                       dtype=jnp.float32, init="zeros"),
        "conv": ParamSpec((n_rec, batch, cfg.conv1d_width - 1, w),
                          (None, "batch", None, None), dtype=cfg.adt, init="zeros"),
        "lengths": ParamSpec((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


def window_attention_core(q, k_new, v_new, kc, vc, pos_c, positions, tv, *,
                          window: int, hd: int):
    """Exact sliding-window attention for a serving chunk over a ring cache.

    q (B,Hq,C,hd) and k_new/v_new (B,Hkv,C,hd) are the chunk's projections;
    kc/vc (B,Hkv,W,hd) + pos_c (B,W) are the ring *as of the chunk start*
    (-1 = empty entry); positions (B,C) absolute query positions; tv (B,C)
    lane validity. Query t attends ring entries in its window plus chunk
    keys s <= t — exactly the keys a token-by-token replay would see, so
    chunked prefill is causality-exact regardless of how the stream was
    chunked (the chunk's writes happen only *after* this attention; a write
    during the chunk could recycle a ring entry an earlier query needs).
    Decode is the C == 1 special case. Pure in its static kwargs so the
    shard_map wrapper (distributed/shard_attn.py) can run it per-shard.
    """
    B, Hq, C, _ = q.shape
    Hkv, W = kc.shape[1], kc.shape[2]
    G = Hq // Hkv  # GQA/MQA: query heads stay with their kv-head group
    scale = 1.0 / (hd ** 0.5)
    qf = q.reshape(B, Hkv, G, C, hd).astype(jnp.float32) * scale
    pq = positions[:, :, None]  # (B,C,1)
    # ring part: keys written before the chunk, inside the query's window
    sr = jnp.einsum("bkgtd,bkwd->bkgtw", qf, kc.astype(jnp.float32))
    pr = pos_c[:, None, :]  # (B,1,W)
    ok_r = (pr >= 0) & (pr < pq) & (pr > pq - window)  # (B,C,W)
    sr = jnp.where(ok_r[:, None, None], sr, -1e9)
    # intra-chunk part: valid causal keys inside the window (incl. self)
    sc = jnp.einsum("bkgtd,bksd->bkgts", qf, k_new.astype(jnp.float32))
    rel = pq - positions[:, None, :]  # (B,C,C) query pos - key pos
    ok_c = tv[:, None, :] & (rel >= 0) & (rel < window)
    sc = jnp.where(ok_c[:, None, None], sc, -1e9)
    p = jax.nn.softmax(jnp.concatenate([sr, sc], axis=-1), axis=-1)
    o = (jnp.einsum("bkgtw,bkwd->bkgtd", p[..., :W], vc.astype(jnp.float32))
         + jnp.einsum("bkgts,bksd->bkgtd", p[..., W:],
                      v_new.astype(jnp.float32)))
    return o.reshape(B, Hq, C, hd).astype(q.dtype)


def _window_attention(q, k_new, v_new, kc, vc, pos_c, positions, tv,
                      cfg: ModelConfig):
    """Serving window attention; shard_map'd under a mesh when cfg asks."""
    if cfg.attn_spec.shard:
        from repro.distributed.shard_attn import sharded_window_attention

        out = sharded_window_attention(q, k_new, v_new, kc, vc, pos_c,
                                       positions, tv,
                                       window=cfg.local_window, hd=cfg.hd)
        if out is not None:
            return out
    return window_attention_core(q, k_new, v_new, kc, vc, pos_c, positions,
                                 tv, window=cfg.local_window, hd=cfg.hd)


def decode_step(params, cfg: ModelConfig, cache, tokens, active=None):
    """One serving decode step; ``active`` (B,) bool freezes inactive slots.

    Frozen slots (active=False) keep every cache leaf bit-identical: all
    writes are ``jnp.where``-guarded on the mask rather than relying on
    arithmetic no-ops (-0.0 + 0.0 == +0.0 would silently flip sign bits).
    """
    B = tokens.shape[0]
    act = jnp.ones((B,), bool) if active is None else active.astype(bool)
    lengths = cache["lengths"] + act.astype(cache["lengths"].dtype)
    pos_now = lengths - 1  # (B,); -1 on frozen empty slots (writes masked)
    x = L.embed(tokens[:, None], params["embed"], cfg)  # (B,1,d)
    new_cache = dict(cache)
    b_idx = jnp.arange(B)
    ia = ir = 0
    W = cache["k"].shape[3]
    for kind, p in _layers_iter(params, cfg):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            q, k_new, v_new = L.qkv_project(h, p["attn"], cfg, pos_now[:, None])
            kc, vc = new_cache["k"][ia], new_cache["v"][ia]
            pc = new_cache["kv_pos"][ia]
            # attend before writing: ring as-of-step-start + self via the
            # chunk part (so a wrapping write can't evict a needed entry)
            o = _window_attention(q, k_new, v_new, kc, vc, pc,
                                  pos_now[:, None], act[:, None], cfg)
            slot = pos_now % W  # -1 % W == W-1: in-bounds, write masked
            kw = kc.at[b_idx, :, slot].set(k_new[:, :, 0].astype(kc.dtype))
            vw = vc.at[b_idx, :, slot].set(v_new[:, :, 0].astype(vc.dtype))
            pw = pc.at[b_idx, slot].set(pos_now)
            new_cache["k"] = new_cache["k"].at[ia].set(
                jnp.where(act[:, None, None, None], kw, kc))
            new_cache["v"] = new_cache["v"].at[ia].set(
                jnp.where(act[:, None, None, None], vw, vc))
            new_cache["kv_pos"] = new_cache["kv_pos"].at[ia].set(
                jnp.where(act[:, None], pw, pc))
            x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
            ia += 1
        else:
            pr = p["rglru"]
            adt = x.dtype
            u = jnp.einsum("bsd,dw->bsw", h, pr["wx"].astype(adt))[:, 0]  # (B,w)
            y = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, pr["wy"].astype(adt)))[:, 0]
            conv_st = new_cache["conv"][ir]  # (B,K-1,w)
            xp = jnp.concatenate([conv_st.astype(adt), u[:, None]], axis=1)  # (B,K,w)
            K = cfg.conv1d_width
            cw = pr["conv_w"].astype(adt)
            u = sum(xp[:, i] * cw[i] for i in range(K)) + pr["conv_b"].astype(adt)
            new_cache["conv"] = new_cache["conv"].at[ir].set(
                jnp.where(act[:, None, None],
                          xp[:, 1:].astype(cache["conv"].dtype), conv_st))
            uf = u.astype(jnp.float32)
            r = jax.nn.sigmoid(uf @ pr["wa"].astype(jnp.float32) + pr["ba"].astype(jnp.float32))
            i_g = jax.nn.sigmoid(uf @ pr["wi"].astype(jnp.float32) + pr["bi"].astype(jnp.float32))
            a, mult = _decay(pr["lam"], r)
            h0 = new_cache["h"][ir]
            hst = a * h0 + mult * (i_g * uf)
            new_cache["h"] = new_cache["h"].at[ir].set(
                jnp.where(act[:, None], hst, h0))
            out = hst.astype(adt) * y
            x = x + jnp.einsum("bw,wd->bd", out, pr["wo"].astype(adt))[:, None]
            ir += 1
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache["lengths"] = lengths
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, cache):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(tokens, params["embed"], cfg)
    new_cache = dict(cache)
    ia = ir = 0
    W = cache["k"].shape[3]
    positions = jnp.arange(S)
    for kind, p in _layers_iter(params, cfg):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
            o = self_attention(q, k, v, _local_spec(cfg), causal=True)
            x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
            # last W positions into the ring buffer at slot pos % W
            take = min(W, S)
            last_pos = jnp.arange(S - take, S)
            slots = last_pos % W
            kc = new_cache["k"][ia].at[:, :, slots].set(
                k[:, :, S - take :].astype(cache["k"].dtype))
            vc = new_cache["v"][ia].at[:, :, slots].set(
                v[:, :, S - take :].astype(cache["v"].dtype))
            pc = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(last_pos[None, :])
            new_cache["k"] = new_cache["k"].at[ia].set(kc)
            new_cache["v"] = new_cache["v"].at[ia].set(vc)
            new_cache["kv_pos"] = new_cache["kv_pos"].at[ia].set(pc)
            ia += 1
        else:
            pr = p["rglru"]
            adt = x.dtype
            u = jnp.einsum("btd,dw->btw", h, pr["wx"].astype(adt))
            y = jax.nn.gelu(jnp.einsum("btd,dw->btw", h, pr["wy"].astype(adt)))
            u_full = _causal_conv(u, pr["conv_w"], pr["conv_b"])
            uf = u_full.astype(jnp.float32)
            r = jax.nn.sigmoid(uf @ pr["wa"].astype(jnp.float32) + pr["ba"].astype(jnp.float32))
            i_g = jax.nn.sigmoid(uf @ pr["wi"].astype(jnp.float32) + pr["bi"].astype(jnp.float32))
            a, mult = _decay(pr["lam"], r)
            _, hseq = _rglru_scan(a, mult * (i_g * uf))
            new_cache["h"] = new_cache["h"].at[ir].set(hseq[:, -1])
            Kw = cfg.conv1d_width
            new_cache["conv"] = new_cache["conv"].at[ir].set(
                u[:, S - (Kw - 1) :].astype(cache["conv"].dtype))
            out = hseq.astype(adt) * y
            x = x + jnp.einsum("btw,wd->btd", out, pr["wo"].astype(adt))
            ir += 1
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    new_cache["lengths"] = jnp.full_like(cache["lengths"], S)
    return logits[:, 0], new_cache


def layer_cache_kinds(cfg: ModelConfig):
    """Per-layer cache kinds for the serving cache factory (DESIGN.md §12).

    The hybrid pattern maps local-attention layers to sliding-window ring
    entries and RG-LRU layers to O(1) recurrent state — both live in the
    same HybridWindowCache tree, selected here per layer."""
    return ["window" if k == "local" else "rglru" for k in _pattern(cfg)]


def prefill_chunk(params, cfg: ModelConfig, cache, tokens, num_valid, *,
                  all_logits=False, collect_kv=False):
    """Ragged chunked prefill: per-slot ``num_valid`` tokens of (B,C) land in
    the serving cache in one dispatch (DESIGN.md §12).

    Invalid lanes are inert: window layers drop their ring writes (OOB
    scatter index + mode="drop"), RG-LRU layers ride the state through with
    decay 1 / input 0 lanes and ``where``-guarded state writes, so a slot
    fed 0 tokens stays bit-identical. The engine clamps C to the window
    (``HybridWindowCache.chunk_cap``) so a chunk's ring scatter indices are
    distinct — two chunk tokens may not recycle the same ring entry inside
    one dispatch.
    """
    if collect_kv:
        raise NotImplementedError(
            "speculative drafting needs the MRA paged-KV cache; the hybrid "
            "window cache does not collect per-chunk K/V")
    B, C = tokens.shape
    nv = num_valid.astype(jnp.int32)
    positions = cache["lengths"][:, None] + jnp.arange(C)[None, :]  # (B,C)
    tv = jnp.arange(C)[None, :] < nv[:, None]  # (B,C) lane validity
    gate = nv > 0
    x = L.embed(tokens, params["embed"], cfg)
    new_cache = dict(cache)
    b_idx = jnp.arange(B)
    ia = ir = 0
    W = cache["k"].shape[3]
    Kw = cfg.conv1d_width
    for kind, p in _layers_iter(params, cfg):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
            kc, vc = new_cache["k"][ia], new_cache["v"][ia]
            pc = new_cache["kv_pos"][ia]
            o = _window_attention(q, k, v, kc, vc, pc, positions, tv, cfg)
            x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
            # valid chunk tokens into the ring at pos % W; invalid lanes get
            # index W (out of bounds) and are dropped — with C <= W the valid
            # indices within a row are distinct, so scatter order can't matter
            widx = jnp.where(tv, positions % W, W)  # (B,C)
            new_cache["k"] = new_cache["k"].at[ia].set(
                kc.at[b_idx[:, None], :, widx].set(
                    k.transpose(0, 2, 1, 3).astype(kc.dtype), mode="drop"))
            new_cache["v"] = new_cache["v"].at[ia].set(
                vc.at[b_idx[:, None], :, widx].set(
                    v.transpose(0, 2, 1, 3).astype(vc.dtype), mode="drop"))
            new_cache["kv_pos"] = new_cache["kv_pos"].at[ia].set(
                pc.at[b_idx[:, None], widx].set(positions, mode="drop"))
            ia += 1
        else:
            pr = p["rglru"]
            adt = x.dtype
            u = jnp.einsum("btd,dw->btw", h, pr["wx"].astype(adt))
            y = jax.nn.gelu(jnp.einsum("btd,dw->btw", h, pr["wy"].astype(adt)))
            conv_st = new_cache["conv"][ir]  # (B,Kw-1,w)
            u_conv = _causal_conv(u, pr["conv_w"], pr["conv_b"], state=conv_st)
            # next conv state: the last Kw-1 *valid* raw inputs, counting the
            # carried state — row layout [state | u], valid run ends at
            # index Kw-1+nv, so gather [nv, nv+Kw-1) (== old state when nv=0)
            xfull = jnp.concatenate([conv_st.astype(adt), u], axis=1)
            cidx = nv[:, None] + jnp.arange(Kw - 1)[None, :]  # (B,Kw-1)
            new_conv = jnp.take_along_axis(
                xfull, cidx[:, :, None], axis=1).astype(conv_st.dtype)
            new_cache["conv"] = new_cache["conv"].at[ir].set(
                jnp.where(gate[:, None, None], new_conv, conv_st))
            uf = u_conv.astype(jnp.float32)
            r = jax.nn.sigmoid(uf @ pr["wa"].astype(jnp.float32)
                               + pr["ba"].astype(jnp.float32))
            i_g = jax.nn.sigmoid(uf @ pr["wi"].astype(jnp.float32)
                                 + pr["bi"].astype(jnp.float32))
            a, mult = _decay(pr["lam"], r)
            # invalid lanes: decay exactly 1, input exactly 0 — the carried
            # state rides through the scan untouched
            a_m = jnp.where(tv[:, :, None], a, 1.0)
            bx_m = jnp.where(tv[:, :, None], mult * (i_g * uf), 0.0)
            acum, h_scan = _rglru_scan(a_m, bx_m)
            h0 = new_cache["h"][ir]  # (B,w) fp32
            hseq = acum * h0[:, None] + h_scan  # (B,C,w)
            last = jnp.clip(nv - 1, 0, C - 1)
            h_last = jnp.take_along_axis(hseq, last[:, None, None], axis=1)[:, 0]
            new_cache["h"] = new_cache["h"].at[ir].set(
                jnp.where(gate[:, None], h_last, h0))
            out = hseq.astype(adt) * y
            x = x + jnp.einsum("btw,wd->btd", out, pr["wo"].astype(adt))
            ir += 1
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    new_cache["lengths"] = cache["lengths"] + nv
    if all_logits:
        return L.unembed(x, params["embed"], cfg), new_cache
    last = jnp.clip(nv - 1, 0, C - 1)
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)
    return L.unembed(xl, params["embed"], cfg)[:, 0], new_cache
