"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Temporal blocks repeat the pattern (rglru, rglru, local): two gated-linear-
recurrence blocks per local-attention block. The RG-LRU diagonal recurrence

    a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is evaluated with ``jax.lax.associative_scan`` (log-depth, no while loop —
accurate XLA cost analysis, DESIGN.md §6). Local attention is MQA
(kv_heads=1) over a sliding window; the window cache is a ring buffer so
``long_500k`` decoding needs O(window) memory — the arch's selling point.

MRA applies to the local-attention layers only (DESIGN.md §5): set
``cfg.attention.kind="mra2"`` to route them through the paper's scheme.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionSpec, self_attention
from . import layers as L
from .params import ParamSpec

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


def _pattern(cfg: ModelConfig):
    pat = cfg.block_pattern or ("rglru", "rglru", "local")
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def _rglru_specs(cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    pdt = cfg.pdt
    return {
        "wx": ParamSpec((d, w), ("d_model", "d_ff"), dtype=pdt),
        "wy": ParamSpec((d, w), ("d_model", "d_ff"), dtype=pdt),
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, "d_ff"), dtype=pdt, scale=0.1),
        "conv_b": ParamSpec((w,), ("d_ff",), dtype=pdt, init="zeros"),
        "wa": ParamSpec((w, w), ("d_ff", None), dtype=pdt, scale=0.01),
        "ba": ParamSpec((w,), (None,), dtype=pdt, init="zeros"),
        "wi": ParamSpec((w, w), ("d_ff", None), dtype=pdt, scale=0.01),
        "bi": ParamSpec((w,), (None,), dtype=pdt, init="zeros"),
        "lam": ParamSpec((w,), ("d_ff",), dtype=pdt, init="embed", scale=0.5),
        "wo": ParamSpec((w, d), ("d_ff", "d_model"), dtype=pdt),
    }


def layer_specs(cfg: ModelConfig, kind: str):
    p = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg)}
    if kind == "local":
        p["attn"] = L.attn_specs(cfg)
    else:
        p["rglru"] = _rglru_specs(cfg)
    p["mlp"] = L.mlp_specs(cfg)
    return p


def param_specs(cfg: ModelConfig):
    kinds = _pattern(cfg)
    if cfg.scan_layers:
        from .params import stack_specs

        pat = cfg.block_pattern or ("rglru", "rglru", "local")
        n_groups = cfg.num_layers // len(pat)
        tail = kinds[n_groups * len(pat) :]
        return {
            "embed": L.embed_specs(cfg),
            "ln_f": L.norm_specs(cfg),
            "groups": stack_specs([layer_specs(cfg, k) for k in pat], n_groups),
            "tail": [layer_specs(cfg, k) for k in tail],
        }
    return {
        "embed": L.embed_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "layers": [layer_specs(cfg, k) for k in kinds],
    }


def _layers_iter(params, cfg: ModelConfig):
    """(kind, layer-params) pairs regardless of stacking."""
    kinds = _pattern(cfg)
    if not cfg.scan_layers:
        return list(zip(kinds, params["layers"]))
    from .params import layer_slice

    pat = cfg.block_pattern or ("rglru", "rglru", "local")
    n_groups = cfg.num_layers // len(pat)
    out = []
    for i in range(n_groups):
        grp = layer_slice(params["groups"], i)
        for j, kind in enumerate(pat):
            out.append((kind, grp[j]))
    for kind, p in zip(kinds[n_groups * len(pat) :], params["tail"]):
        out.append((kind, p))
    return out


# --------------------------------------------------------------------------- #
# RG-LRU block
# --------------------------------------------------------------------------- #
def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B,T,W); w (K,W). state (B,K-1,W) or None."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype)


def _rglru_scan(a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan over T. a/bx (B,T,W)."""

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, av * bu + bv

    return jax.lax.associative_scan(combine, (a, bx), axis=1)


def _decay(lam, gate):
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * gate
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a): use expm1 for stability near a=1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, mult


def rglru_block(x, p, cfg: ModelConfig):
    """x (B,T,d) -> (B,T,d)."""
    adt = x.dtype
    u = jnp.einsum("btd,dw->btw", x, p["wx"].astype(adt))
    y = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["wy"].astype(adt)))
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"].astype(jnp.float32))
    a, mult = _decay(p["lam"], r)
    _, h = _rglru_scan(a, mult * (i * uf))
    out = (h.astype(adt) * y)
    return jnp.einsum("btw,wd->btd", out, p["wo"].astype(adt))


# --------------------------------------------------------------------------- #
# Full-sequence forward
# --------------------------------------------------------------------------- #
def _local_spec(cfg: ModelConfig) -> AttentionSpec:
    # attn_spec (not attention): honor the model-level kernel routing
    if cfg.attention.kind in ("mra2", "mra2_s"):
        return cfg.attn_spec
    import dataclasses

    return dataclasses.replace(cfg.attn_spec, kind="local",
                               local_window=cfg.local_window)


def forward(params, cfg: ModelConfig, batch, *, key_mask=None):
    x = L.embed(batch["tokens"], params["embed"], cfg)

    def body(x, p, kind):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            x = x + L.attn_block(h, p["attn"], cfg, spec=_local_spec(cfg),
                                 key_mask=key_mask)
        else:
            x = x + rglru_block(h, p["rglru"], cfg)
        h = L.apply_norm(x, p["ln2"], cfg)
        return x + L.mlp_block(h, p["mlp"], cfg)

    if cfg.scan_layers:
        pat = cfg.block_pattern or ("rglru", "rglru", "local")

        def group_body(x, grp):
            for j, kind in enumerate(pat):
                x = body(x, grp[j], kind)
            return x, None

        x, _ = jax.lax.scan(L.remat_wrap(group_body, cfg), x, params["groups"])
        n_groups = cfg.num_layers // len(pat)
        kinds = _pattern(cfg)[n_groups * len(pat) :]
        for p, kind in zip(params["tail"], kinds):
            fn = L.remat_wrap(functools.partial(body, kind=kind), cfg)
            x = fn(x, p)
    else:
        for kind, p in _layers_iter(params, cfg):
            fn = L.remat_wrap(functools.partial(body, kind=kind), cfg)
            x = fn(x, p)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return L.unembed(x, params["embed"], cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch, *, key_mask=None):
    logits, _ = forward(params, cfg, batch)
    loss = jnp.mean(L.lm_nll(logits, batch["targets"], cfg))
    return loss, {"loss": loss, "nll": loss}


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    kinds = _pattern(cfg)
    n_attn = sum(1 for k in kinds if k == "local")
    n_rec = len(kinds) - n_attn
    w = cfg.lru_width or cfg.d_model
    W = min(cfg.local_window, max_len)
    return {
        "k": ParamSpec((n_attn, batch, cfg.kv_heads, W, cfg.hd),
                       (None, "batch", None, None, None), dtype=cfg.adt, init="zeros"),
        "v": ParamSpec((n_attn, batch, cfg.kv_heads, W, cfg.hd),
                       (None, "batch", None, None, None), dtype=cfg.adt, init="zeros"),
        "kv_pos": ParamSpec((n_attn, batch, W), (None, "batch", None),
                            dtype=jnp.int32, init="zeros"),
        "h": ParamSpec((n_rec, batch, w), (None, "batch", "d_ff"),
                       dtype=jnp.float32, init="zeros"),
        "conv": ParamSpec((n_rec, batch, cfg.conv1d_width - 1, w),
                          (None, "batch", None, "d_ff"), dtype=cfg.adt, init="zeros"),
        "lengths": ParamSpec((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


def _ring_decode_attn(q, kc, vc, pos_c, pos_now, cfg: ModelConfig):
    """Decode attention over a ring-buffer window cache.

    q (B,H,1,hd); kc/vc (B,1,W,hd); pos_c (B,W) absolute positions (-1 empty).
    """
    B, Hq = q.shape[:2]
    scale = 1.0 / (cfg.hd ** 0.5)
    qg = q.reshape(B, 1, Hq, cfg.hd).astype(jnp.float32)
    s = jnp.einsum("bkhd,bkjd->bhj", qg, kc.astype(jnp.float32)) * scale
    ok = (pos_c >= 0) & (pos_c <= pos_now[:, None]) & (
        pos_c > pos_now[:, None] - cfg.local_window
    )
    s = jnp.where(ok[:, None, :], s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhj,bkjd->bhd", p, vc.astype(jnp.float32))
    return o.reshape(B, Hq, 1, cfg.hd).astype(q.dtype)


def decode_step(params, cfg: ModelConfig, cache, tokens):
    B = tokens.shape[0]
    lengths = cache["lengths"] + 1
    pos_now = lengths - 1  # (B,)
    x = L.embed(tokens[:, None], params["embed"], cfg)  # (B,1,d)
    new_cache = dict(cache)
    b_idx = jnp.arange(B)
    ia = ir = 0
    W = cache["k"].shape[3]
    for kind, p in _layers_iter(params, cfg):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            q, k_new, v_new = L.qkv_project(h, p["attn"], cfg, pos_now[:, None])
            slot = pos_now % W
            kc = new_cache["k"][ia].at[b_idx, :, slot].set(
                k_new[:, :, 0].astype(cache["k"].dtype))
            vc = new_cache["v"][ia].at[b_idx, :, slot].set(
                v_new[:, :, 0].astype(cache["v"].dtype))
            pc = new_cache["kv_pos"][ia].at[b_idx, slot].set(pos_now)
            new_cache["k"] = new_cache["k"].at[ia].set(kc)
            new_cache["v"] = new_cache["v"].at[ia].set(vc)
            new_cache["kv_pos"] = new_cache["kv_pos"].at[ia].set(pc)
            o = _ring_decode_attn(q, kc, vc, pc, pos_now, cfg)
            x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
            ia += 1
        else:
            pr = p["rglru"]
            adt = x.dtype
            u = jnp.einsum("bsd,dw->bsw", h, pr["wx"].astype(adt))[:, 0]  # (B,w)
            y = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, pr["wy"].astype(adt)))[:, 0]
            conv_st = new_cache["conv"][ir]  # (B,K-1,w)
            xp = jnp.concatenate([conv_st.astype(adt), u[:, None]], axis=1)  # (B,K,w)
            K = cfg.conv1d_width
            cw = pr["conv_w"].astype(adt)
            u = sum(xp[:, i] * cw[i] for i in range(K)) + pr["conv_b"].astype(adt)
            new_cache["conv"] = new_cache["conv"].at[ir].set(
                xp[:, 1:].astype(cache["conv"].dtype))
            uf = u.astype(jnp.float32)
            r = jax.nn.sigmoid(uf @ pr["wa"].astype(jnp.float32) + pr["ba"].astype(jnp.float32))
            i_g = jax.nn.sigmoid(uf @ pr["wi"].astype(jnp.float32) + pr["bi"].astype(jnp.float32))
            a, mult = _decay(pr["lam"], r)
            hst = a * new_cache["h"][ir] + mult * (i_g * uf)
            new_cache["h"] = new_cache["h"].at[ir].set(hst)
            out = hst.astype(adt) * y
            x = x + jnp.einsum("bw,wd->bd", out, pr["wo"].astype(adt))[:, None]
            ir += 1
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache["lengths"] = lengths
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, cache):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(tokens, params["embed"], cfg)
    new_cache = dict(cache)
    ia = ir = 0
    W = cache["k"].shape[3]
    positions = jnp.arange(S)
    for kind, p in _layers_iter(params, cfg):
        h = L.apply_norm(x, p["ln1"], cfg)
        if kind == "local":
            q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
            o = self_attention(q, k, v, _local_spec(cfg), causal=True)
            x = x + jnp.einsum("bhsk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
            # last W positions into the ring buffer at slot pos % W
            take = min(W, S)
            last_pos = jnp.arange(S - take, S)
            slots = last_pos % W
            kc = new_cache["k"][ia].at[:, :, slots].set(
                k[:, :, S - take :].astype(cache["k"].dtype))
            vc = new_cache["v"][ia].at[:, :, slots].set(
                v[:, :, S - take :].astype(cache["v"].dtype))
            pc = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(last_pos[None, :])
            new_cache["k"] = new_cache["k"].at[ia].set(kc)
            new_cache["v"] = new_cache["v"].at[ia].set(vc)
            new_cache["kv_pos"] = new_cache["kv_pos"].at[ia].set(pc)
            ia += 1
        else:
            pr = p["rglru"]
            adt = x.dtype
            u = jnp.einsum("btd,dw->btw", h, pr["wx"].astype(adt))
            y = jax.nn.gelu(jnp.einsum("btd,dw->btw", h, pr["wy"].astype(adt)))
            u_full = _causal_conv(u, pr["conv_w"], pr["conv_b"])
            uf = u_full.astype(jnp.float32)
            r = jax.nn.sigmoid(uf @ pr["wa"].astype(jnp.float32) + pr["ba"].astype(jnp.float32))
            i_g = jax.nn.sigmoid(uf @ pr["wi"].astype(jnp.float32) + pr["bi"].astype(jnp.float32))
            a, mult = _decay(pr["lam"], r)
            _, hseq = _rglru_scan(a, mult * (i_g * uf))
            new_cache["h"] = new_cache["h"].at[ir].set(hseq[:, -1])
            Kw = cfg.conv1d_width
            new_cache["conv"] = new_cache["conv"].at[ir].set(
                u[:, S - (Kw - 1) :].astype(cache["conv"].dtype))
            out = hseq.astype(adt) * y
            x = x + jnp.einsum("btw,wd->btd", out, pr["wo"].astype(adt))
            ir += 1
        h = L.apply_norm(x, p["ln2"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
    x = L.apply_norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    new_cache["lengths"] = jnp.full_like(cache["lengths"], S)
    return logits[:, 0], new_cache
