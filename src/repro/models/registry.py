"""Model registry: family name -> module implementing the model API.

Every family module provides:
  param_specs(cfg)                     -> ParamSpec tree
  loss_fn(params, cfg, batch)          -> (loss, metrics)      [train_step]
  forward(params, cfg, batch)          -> (logits, aux)
  cache_specs(cfg, batch, max_len)     -> ParamSpec tree       [serving]
  prefill(params, cfg, batch, cache)   -> (logits, cache)
  decode_step(params, cfg, cache, tok) -> (logits, cache)      [serve_step]
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from . import recurrentgemma, rwkv6, transformer

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "hubert": transformer,
    "internvl": transformer,
    "rwkv6": rwkv6,
    "recurrentgemma": recurrentgemma,
}


def get_model(cfg: ModelConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None
