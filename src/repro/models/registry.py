"""Model registry: family name -> module implementing the model API.

Every family module provides:
  param_specs(cfg)                     -> ParamSpec tree
  loss_fn(params, cfg, batch)          -> (loss, metrics)      [train_step]
  forward(params, cfg, batch)          -> (logits, aux)
  cache_specs(cfg, batch, max_len)     -> ParamSpec tree       [serving]
  prefill(params, cfg, batch, cache)   -> (logits, cache)
  decode_step(params, cfg, cache, tok,
              active=None)             -> (logits, cache)      [serving]

The continuous-batching engine (serve/engine.py, DESIGN.md §9/§12)
additionally requires — and *every* family implements, with identical
signatures (tests/test_registry_contract.py pins them against drift):
  layer_cache_kinds(cfg)               -> per-layer cache-kind strings that
      select the cache backend (serve/cache/): "paged_kv"/"kv" -> ring-paged
      KV, "wkv" -> recurrent state, "window"/"rglru" -> hybrid window cache
  prefill_chunk(params, cfg, cache, tokens, num_valid, *,
                all_logits=False, collect_kv=False) -> (logits, cache)
      ragged chunked prefill, one dispatch for the whole batch
  decode_step honoring ``active`` (B,) bool — inactive slots' cache rows
  preserved bit-for-bit (slot isolation under ragged batching).
A family missing any of these is rejected by Engine at construction with
the list of missing entry points.

Speculative serving (Engine(spec_k=...), DESIGN.md §10) further leans on
``prefill_chunk(..., all_logits=True, collect_kv=True)`` — all-position
logits for draft verification plus the chunk's fp32 K/V for the bounded
ring rewind — and on ``decode_step`` running under the coarse-only
AttentionSpec (the draft pass). Both are the same transformer entry points,
not new model API.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from . import recurrentgemma, rwkv6, transformer

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "hubert": transformer,
    "internvl": transformer,
    "rwkv6": rwkv6,
    "recurrentgemma": recurrentgemma,
}


def get_model(cfg: ModelConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None
