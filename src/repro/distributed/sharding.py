"""Logical-axis → mesh-axis sharding rules with divisibility fallback.

Every parameter / activation carries *logical* axis names ("batch", "heads",
"d_ff", "experts", ...). This module resolves them against a concrete mesh,
preferring the most parallel mapping that actually divides the dimension —
e.g. qwen2's 28 heads do not divide a 16-way model axis, so heads fall back
to replicated while its d_ff = 18944 = 16·1184 still shards (DESIGN.md §4).

Rules are an ordered list of candidate mesh-axis groups per logical axis.
A group is taken iff (a) every mesh axis in it exists, (b) none is already
used by another dimension of the same tensor, and (c) the dimension size is
divisible by the group's total device count.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidate mesh-axis groups in preference order, per logical axis
DEFAULT_RULES: dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),          # sequence/context parallelism (MoE dispatch)
    "kv_seq": (("data",),),        # long-context KV-cache sequence sharding
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "d_ff": (("model",),),
    "experts": (("model",),),
    "expert_ff": (("model",),),    # fallback TP inside experts
    "d_model": (),                 # replicated (activations stay batch-sharded)
    "zero": (("pod", "data"), ("data",)),  # ZeRO-1 optimizer-state sharding
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    def override(self, **kw) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kw)
        return ShardingRules(new)


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return math.prod(mesh.shape[n] for n in names)


def logical_to_pspec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[ShardingRules] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec for ``mesh``."""
    rules = rules or ShardingRules()
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        chosen = None
        for group in rules.rules.get(name, ()) if name else ():
            if not all(a in mesh.shape for a in group):
                continue
            if any(a in used for a in group):
                continue
            if dim % _axis_size(mesh, group) != 0:
                continue
            chosen = group
            break
        if chosen is None:
            parts.append(None)
        else:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
    return P(*parts)


def named_sharding(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[ShardingRules] = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(shape, axes, mesh, rules))


def batch_pspec(mesh: Mesh, ndim: int = 2, rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for a (batch, ...) activation: batch over data axes."""
    lead = logical_to_pspec((1 << 30,), ("batch",), mesh, rules)  # always divisible
    return P(lead[0], *([None] * (ndim - 1)))
