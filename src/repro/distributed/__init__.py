from .sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_pspec,
    named_sharding,
)
from .shard_attn import sharded_decode_attention, sharded_self_attention
