from .sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_pspec,
    named_sharding,
)
