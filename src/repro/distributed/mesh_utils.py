"""Current-mesh context so model code can open shard_map regions.

The launcher (train/serve/dryrun) sets the active mesh; layers that need
explicit collectives (MoE expert parallelism, sequence-parallel decode) read
it. Without an active mesh every layer runs its pure-local path — that is
what CPU unit tests use.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import Mesh

_CURRENT: list[Optional[Mesh]] = [None]


def get_mesh() -> Optional[Mesh]:
    return _CURRENT[0]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _CURRENT[0] = prev


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def has_axis(mesh: Optional[Mesh], name: str) -> bool:
    return mesh is not None and name in mesh.shape
