"""Current-mesh context so model code can open shard_map regions.

The launcher (train/serve/dryrun) sets the active mesh; layers that need
explicit collectives (MoE expert parallelism, sequence-parallel decode) read
it. Without an active mesh every layer runs its pure-local path — that is
what CPU unit tests use.
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Optional

from jax.sharding import Mesh

try:  # jax >= 0.5 exposes shard_map at the top level (mesh keyword-only,
    # check_rep renamed check_vma)
    from jax import shard_map as _shard_map
except ImportError:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = inspect.signature(_shard_map).parameters


def shard_map(f, mesh, in_specs, out_specs, check_rep=True):
    """Version-portable shard_map: one calling convention for every jax.

    Callers use the 0.4.x names (positional-or-keyword ``mesh``,
    ``check_rep``); this forwards keywords and renames ``check_rep`` to
    ``check_vma`` on jax versions that made the switch.
    """
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SM_PARAMS:
        kw["check_vma"] = check_rep
    elif "check_rep" in _SM_PARAMS:
        kw["check_rep"] = check_rep
    return _shard_map(f, **kw)


__all__ = ["get_mesh", "use_mesh", "dp_axes", "has_axis", "shard_map"]

_CURRENT: list[Optional[Mesh]] = [None]


def get_mesh() -> Optional[Mesh]:
    return _CURRENT[0]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _CURRENT[0] = prev


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def has_axis(mesh: Optional[Mesh], name: str) -> bool:
    return mesh is not None and name in mesh.shape
