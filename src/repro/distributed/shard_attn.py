"""shard_map wrappers running attention (incl. the Pallas kernels) per-shard.

Under ``jit`` auto-partitioning XLA cannot see inside a ``pallas_call``, so
the block-sparse kernel would be resolved by gathering its operands onto
every device. MRA-2 attention is *embarrassingly parallel* over (batch,
kv-head): the pyramid, the top-k block selection, and the block-sparse
kernel all act independently per (b, h) slice, and the sequence axis stays
unsharded — so the correct mesh mapping is a ``shard_map`` over

  * batch  -> the data axes ("pod", "data"), and
  * heads  -> the model axis ("model"), kv-head aligned (query heads move
    with their GQA group: q is laid out group-major, Hq = Hkv * G, so
    splitting Hkv over |model| splits Hq into the matching contiguous
    chunks).

Inside the region every path (jnp, Pallas fwd + custom_vjp bwd, and the
fused chunk/decode serving kernel of DESIGN.md §11 — its ``use_kernel`` /
``interpret`` / ``kernel_mode`` fields travel inside the spec dataclass
like every other flag, so the latency and throughput tile shapes both run
per-shard without any code here knowing about them; the in-kernel top-m
selection is per-(batch, kv-head) independent exactly like the rest of
the math) runs its ordinary single-device code on the local shard; no
collectives are needed in the forward, and the backward's grad all-reduce
over the batch axes is the ``shard_map`` transpose of the batch in_specs (a
psum placed by JAX, not by us — see DESIGN.md §8).

Dispatch contract: callers (core/attention.py) route here when
``AttentionSpec.shard`` is set; these functions return ``None`` when no mesh
is active or when the shapes do not divide the mesh axes, and the caller
falls through to the bit-identical single-device path. Divisibility
fallback mirrors distributed/sharding.py: an axis that does not divide is
replicated, never an error.

The speculative draft path (DESIGN.md §10) rides through unchanged: the
coarse-only draft is just an ``AttentionSpec`` with ``coarse_only`` set, and
the spec dataclass travels into the shard_map body verbatim
(``spec.replace(shard=False)`` keeps every other field), so draft decode
steps and chunked verify dispatches run under the same DP×TP mapping as
plain serving — coarse selection and the pyramid background are per-(batch,
kv-head) independent exactly like the budgeted variants. TP spec-engine
parity is pinned in the shard CI tier (tests/test_engine.py).
"""
from __future__ import annotations

import math
from typing import Optional

from jax.sharding import PartitionSpec as P

from . import mesh_utils

# attention kinds whose per-(batch, kv-head) slices are independent; the
# baselines (never on the production path) are excluded.
SHARDABLE_KINDS = ("full", "mra2", "mra2_s", "local")


def _batch_axes(mesh, batch: int):
    """Data axes that divide ``batch`` (greedy, widest first), possibly ()."""
    dp = mesh_utils.dp_axes(mesh)
    while dp and batch % math.prod(mesh.shape[a] for a in dp) != 0:
        dp = dp[1:]
    return dp


def _head_axis(mesh, kv_heads: int) -> Optional[str]:
    """"model" when the kv-head axis divides it (GQA stays aligned), else None."""
    if not mesh_utils.has_axis(mesh, "model") or mesh.shape["model"] == 1:
        return None
    return "model" if kv_heads % mesh.shape["model"] == 0 else None


def attention_partition(mesh, batch: int, kv_heads: int):
    """(batch_part, head_part) PartitionSpec entries, or None if unshardable.

    Public so callers that pre-place operands (benchmarks, engines) use the
    *same* decision as the shard_map in_specs — a tensor placed by a
    different rule would be resharded on entry.
    """
    dp = _batch_axes(mesh, batch)
    hax = _head_axis(mesh, kv_heads)
    if not dp and hax is None:
        return None
    return (dp if dp else None), hax


def sharded_self_attention(q, k, v, spec, *, causal, key_mask=None):
    """shard_map'd full-sequence attention; None if the mesh can't shard it."""
    mesh = mesh_utils.get_mesh()
    if mesh is None or spec.kind not in SHARDABLE_KINDS:
        return None
    parts = attention_partition(mesh, q.shape[0], k.shape[1])
    if parts is None:
        return None
    bpart, hpart = parts
    s4 = P(bpart, hpart, None, None)
    local_spec = spec.replace(shard=False)

    args = {"q": q, "k": k, "v": v}
    in_specs = {"q": s4, "k": s4, "v": s4}
    if key_mask is not None:
        args["km"] = key_mask
        in_specs["km"] = P(bpart, None)

    def body(a):
        from repro.core.attention import self_attention

        return self_attention(
            a["q"], a["k"], a["v"], local_spec, causal=causal,
            key_mask=a.get("km"),
        )

    return mesh_utils.shard_map(
        body, mesh, in_specs=(in_specs,), out_specs=s4, check_rep=False
    )(args)


def _sharded_kv_attention(q, k_cache, v_cache, lengths, spec, *, q_pos=None,
                          pyramid=None, page_blocks=None, k_scale=None,
                          v_scale=None):
    """Shared shard_map plumbing for attention over the decode state.

    The KV cache, the pyramid block sums, and the int8 dequant scales all
    carry (batch, kv_heads, ...) leading axes, so one (batch -> data,
    kv_heads -> model) mapping covers the whole state; ``lengths``, the ring
    page table (``page_blocks``, shared by every kv head), and the chunk
    query positions (``q_pos``, whose presence selects the chunked-prefill
    callee over single-token decode) shard over batch only. Returns None
    when the mesh can't shard it.
    """
    mesh = mesh_utils.get_mesh()
    if mesh is None or spec.kind not in SHARDABLE_KINDS:
        return None
    parts = attention_partition(mesh, q.shape[0], k_cache.shape[1])
    if parts is None:
        return None
    bpart, hpart = parts
    s4 = P(bpart, hpart, None, None)
    s3 = P(bpart, hpart, None)
    local_spec = spec.replace(shard=False)

    args = {"q": q, "k": k_cache, "v": v_cache, "len": lengths}
    in_specs = {"q": s4, "k": s4, "v": s4, "len": P(bpart)}
    if q_pos is not None:
        args["qp"] = q_pos
        in_specs["qp"] = P(bpart, None)
    if pyramid is not None:
        args["pk"], args["pv"] = pyramid.k_sum, pyramid.v_sum
        in_specs["pk"] = in_specs["pv"] = s4
        if pyramid.upper is not None:
            # H-level hierarchy (DESIGN.md §14): the collapsed-level + tail
            # means carry the same (batch, kv_heads, ...) leading axes as
            # the pyramid; entry counts shard over batch only (shared by
            # every kv head, like the page table).
            args["uk"] = pyramid.upper.k_mean
            args["uv"] = pyramid.upper.v_mean
            args["uc"] = pyramid.upper.counts
            in_specs["uk"] = in_specs["uv"] = s4
            in_specs["uc"] = P(bpart, None)
    if page_blocks is not None:
        args["pb"] = page_blocks
        in_specs["pb"] = P(bpart, None)
    if k_scale is not None:
        args["ks"], args["vs"] = k_scale, v_scale
        in_specs["ks"] = in_specs["vs"] = s3

    def body(a):
        from repro.core.attention import chunk_attention, decode_attention
        from repro.core.hier import HierUpper
        from repro.core.mra_decode import PyramidState

        upper = (HierUpper(a["uk"], a["uv"], a["uc"])
                 if "uk" in a else None)
        pyr = (PyramidState(a["pk"], a["pv"], upper)
               if "pk" in a else None)
        kw = dict(pyramid=pyr, page_blocks=a.get("pb"), k_scale=a.get("ks"),
                  v_scale=a.get("vs"))
        if "qp" in a:
            return chunk_attention(a["q"], a["k"], a["v"], a["len"], a["qp"],
                                   local_spec, **kw)
        return decode_attention(a["q"], a["k"], a["v"], a["len"], local_spec,
                                **kw)

    return mesh_utils.shard_map(
        body, mesh, in_specs=(in_specs,), out_specs=s4, check_rep=False
    )(args)


def sharded_decode_attention(
    q, k_cache, v_cache, lengths, spec, *, pyramid=None, page_blocks=None,
    k_scale=None, v_scale=None
):
    """shard_map'd single-token decode attention (TP serving path)."""
    return _sharded_kv_attention(
        q, k_cache, v_cache, lengths, spec, pyramid=pyramid,
        page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale)


def sharded_chunk_attention(
    q, k_cache, v_cache, lengths, q_pos, spec, *, pyramid=None,
    page_blocks=None, k_scale=None, v_scale=None
):
    """shard_map'd chunked-prefill attention (serving engine prefill path)."""
    return _sharded_kv_attention(
        q, k_cache, v_cache, lengths, spec, q_pos=q_pos, pyramid=pyramid,
        page_blocks=page_blocks, k_scale=k_scale, v_scale=v_scale)


def sharded_window_attention(q, k_new, v_new, k_cache, v_cache, kv_pos,
                             positions, token_valid, *, window: int, hd: int):
    """shard_map'd sliding-window ring attention (hybrid serving path).

    Same (batch -> data, kv_heads -> model) mapping as the MRA decode state:
    the ring cache and chunk projections are per-(batch, kv-head)
    independent, while ``kv_pos`` (ring entry positions, shared across kv
    heads), ``positions`` and ``token_valid`` shard over batch only. Returns
    None when the mesh can't shard it (caller falls through to the
    bit-identical single-device core).
    """
    mesh = mesh_utils.get_mesh()
    if mesh is None:
        return None
    parts = attention_partition(mesh, q.shape[0], k_cache.shape[1])
    if parts is None:
        return None
    bpart, hpart = parts
    s4 = P(bpart, hpart, None, None)
    s2 = P(bpart, None)

    args = {"q": q, "kn": k_new, "vn": v_new, "kc": k_cache, "vc": v_cache,
            "pc": kv_pos, "pos": positions, "tv": token_valid}
    in_specs = {"q": s4, "kn": s4, "vn": s4, "kc": s4, "vc": s4,
                "pc": s2, "pos": s2, "tv": s2}

    def body(a):
        from repro.models.recurrentgemma import window_attention_core

        return window_attention_core(
            a["q"], a["kn"], a["vn"], a["kc"], a["vc"], a["pc"], a["pos"],
            a["tv"], window=window, hd=hd)

    return mesh_utils.shard_map(
        body, mesh, in_specs=(in_specs,), out_specs=s4, check_rep=False
    )(args)
