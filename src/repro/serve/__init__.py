from .engine import Engine
from .kv_cache import RingPagedKVCache
from .sampling import SamplingParams, sample, sample_batch
from .scheduler import Request, Scheduler, SlotState

__all__ = [
    "Engine",
    "Request",
    "RingPagedKVCache",
    "SamplingParams",
    "Scheduler",
    "SlotState",
    "sample",
    "sample_batch",
]
