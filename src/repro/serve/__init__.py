from .cache import (CacheBackend, HybridWindowCache, RecurrentStateCache,
                    RingPagedKVCache, make_cache)
from .engine import Engine, EngineConfig
from .sampling import SamplingParams, sample, sample_batch
from .scheduler import Request, Scheduler, SlotState
from .speculative import SpecDecoder
from .telemetry import MetricsRegistry, Telemetry, UndeclaredMetric

__all__ = [
    "CacheBackend",
    "Engine",
    "EngineConfig",
    "HybridWindowCache",
    "MetricsRegistry",
    "RecurrentStateCache",
    "Request",
    "RingPagedKVCache",
    "SamplingParams",
    "Scheduler",
    "SlotState",
    "SpecDecoder",
    "Telemetry",
    "UndeclaredMetric",
    "make_cache",
    "sample",
    "sample_batch",
]
