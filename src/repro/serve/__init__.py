from .engine import Engine
from .kv_cache import RingPagedKVCache
from .sampling import SamplingParams, sample, sample_batch
from .scheduler import Request, Scheduler, SlotState
from .speculative import SpecDecoder

__all__ = [
    "Engine",
    "Request",
    "RingPagedKVCache",
    "SamplingParams",
    "Scheduler",
    "SlotState",
    "SpecDecoder",
    "sample",
    "sample_batch",
]
