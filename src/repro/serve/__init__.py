from .cache import (CacheBackend, HybridWindowCache, RecurrentStateCache,
                    RingPagedKVCache, make_cache)
from .engine import Engine, EngineConfig
from .sampling import SamplingParams, sample, sample_batch
from .scheduler import Request, Scheduler, SlotState
from .speculative import SpecDecoder

__all__ = [
    "CacheBackend",
    "Engine",
    "EngineConfig",
    "HybridWindowCache",
    "RecurrentStateCache",
    "Request",
    "RingPagedKVCache",
    "SamplingParams",
    "Scheduler",
    "SlotState",
    "SpecDecoder",
    "make_cache",
    "sample",
    "sample_batch",
]
