"""Continuous-batching scheduler: admission + per-slot state machines.

Host-side bookkeeping for the engine. Each slot runs the state machine

    FREE -> PREFILL -> DECODE -> FREE

with *ragged* per-slot progress: slots prefill different prompts in shared
chunked dispatches, decode at different sequence lengths in shared decode
dispatches, and finish/readmit independently — no "one wave at a time"
alignment. The scheduler only plans (which tokens go into the next prefill
chunk, which slots decode); all device state lives in the engine's cache
backend (serve/cache/) and all numerics in the jitted model functions, so
planning order can never change a request's tokens (tests/test_engine.py).

The plans double as the serving kernel's mode pick (DESIGN.md §11): a
prefill plan feeds a C == chunk ``prefill_chunk`` dispatch (throughput-mode
multi-query tiles under ``kernel_mode="auto"``), a decode mask feeds a
C == 1 ``decode_step`` dispatch (latency-mode single-query tiles), and a
speculative round's verify chunk is a C == spec_k + 1 prefill dispatch
(throughput again) — the scheduler decides *which* dispatch shape runs,
the trace-time chunk width resolves the tile shape.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import List, Optional

import numpy as np

from .sampling import SamplingParams
from .telemetry import Telemetry


@dataclasses.dataclass
class Request:
    """One generation request.

    prompt: (S,) int array of prompt token ids (S may be 0).
    max_new_tokens: number of tokens to sample.
    sampling: per-request sampler settings; None = the engine's
      ``EngineConfig.default_sampling`` (greedy when that is unset too),
      resolved at submit.
    out: filled by the engine — (max_new_tokens,) int32 sampled tokens
      (empty for degenerate requests: empty prompt or max_new_tokens <= 0).
    """

    prompt: np.ndarray
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None
    out: Optional[np.ndarray] = None
    # filled by the engine when serving speculatively (spec_k > 0): drafted
    # tokens of this request that verification accepted (acceptance rate =
    # spec_accepted / drafts offered; DESIGN.md §10)
    spec_accepted: int = 0
    # lifecycle stamps (serve/telemetry.py RequestTrace: submit -> admit ->
    # prefill-done -> first-token -> complete), None with telemetry disabled
    trace: Optional[object] = None


class SlotState(enum.Enum):
    FREE = "free"
    PREFILL = "prefill"
    DECODE = "decode"


@dataclasses.dataclass
class Slot:
    state: SlotState = SlotState.FREE
    req: Optional[Request] = None
    fed: int = 0        # prompt tokens written to the cache so far
    generated: int = 0  # tokens sampled so far (== sampler step index)
    token: int = 0      # next token to feed to decode (last sampled)
    out: List[int] = dataclasses.field(default_factory=list)


class Scheduler:
    """Admission queue + slot state machines for the serving engine.

    capacity: cache window per slot (tokens), or None when the backend
      holds O(1)/O(window) state per slot (recurrent families) and any
      prompt/generation length is admissible. With a capacity, prompts
      longer than it are rejected at submit; when ``ring`` is False (dense
      cache: non-MRA attention kinds) prompt + max_new_tokens must also
      fit — a ring cache instead evicts its oldest background pages, so
      generation length is unbounded.
    """

    def __init__(self, slots: int, capacity: Optional[int], chunk: int, *,
                 ring: bool = True,
                 default_sampling: Optional[SamplingParams] = None,
                 telemetry=None):
        assert chunk >= 1 and (capacity is None or capacity >= 1)
        self.capacity = capacity
        self.chunk = chunk if capacity is None else min(chunk, capacity)
        self.ring = ring
        self.default_sampling = default_sampling
        self.slots = [Slot() for _ in range(slots)]
        self.pending: deque = deque()
        self.done: List[Request] = []
        # ragged per-slot accepted-draft totals roll up here (spec decoding)
        self.spec_accepted_total = 0
        # lifecycle stamping (serve/telemetry.py); None = disabled no-op
        # (direct construction in tests) — the engine always passes its own
        self.telemetry = telemetry or Telemetry(enabled=False)

    # ---- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        plen = int(len(req.prompt))
        if req.sampling is None:
            req.sampling = self.default_sampling or SamplingParams()
        self.telemetry.on_submit(req)
        if self.capacity is not None:
            if plen > self.capacity:
                raise ValueError(
                    f"prompt of {plen} tokens exceeds the engine's per-slot "
                    f"capacity of {self.capacity}")
            if not self.ring and plen + req.max_new_tokens > self.capacity:
                raise ValueError(
                    f"prompt {plen} + max_new_tokens {req.max_new_tokens} "
                    f"exceeds the dense cache capacity {self.capacity} "
                    "(only the MRA ring-paged cache evicts)")
        if plen == 0 or req.max_new_tokens <= 0:
            # degenerate: nothing to condition on / nothing to sample — done
            # without occupying a slot or issuing a spurious decode step
            req.out = np.array([], np.int32)
            self.done.append(req)
            self.telemetry.on_complete(req)
            return
        self.pending.append(req)

    def admit(self) -> List[int]:
        """Bind pending requests to free slots; returns newly admitted ids."""
        newly = []
        for s, slot in enumerate(self.slots):
            if slot.state is SlotState.FREE and self.pending:
                req = self.pending.popleft()
                self.slots[s] = Slot(state=SlotState.PREFILL, req=req)
                self.telemetry.on_admit(req, s)
                newly.append(s)
        return newly

    # ---- prefill planning --------------------------------------------------
    def prefill_plan(self):
        """Next chunk of prompt tokens per prefilling slot, or None.

        Returns (tokens (n_slots, chunk) int32, num_valid (n_slots,) int32,
        finishing list of slot ids whose prompt completes with this chunk).
        Commits the plan: callers must execute it exactly once.
        """
        if not any(s.state is SlotState.PREFILL for s in self.slots):
            return None
        n = len(self.slots)
        tokens = np.zeros((n, self.chunk), np.int32)
        num_valid = np.zeros((n,), np.int32)
        finishing = []
        for s, slot in enumerate(self.slots):
            if slot.state is not SlotState.PREFILL:
                continue
            prompt = np.asarray(slot.req.prompt, np.int32)
            take = min(self.chunk, len(prompt) - slot.fed)
            tokens[s, :take] = prompt[slot.fed : slot.fed + take]
            num_valid[s] = take
            slot.fed += take
            if slot.fed == len(prompt):
                slot.state = SlotState.DECODE
                finishing.append(s)
        return tokens, num_valid, finishing

    # ---- decode planning ---------------------------------------------------
    def decode_mask(self) -> np.ndarray:
        """(n_slots,) bool — slots with a token to feed this step."""
        return np.array(
            [s.state is SlotState.DECODE and s.generated > 0 for s in self.slots],
            bool)

    def any_sampling(self, slots=None) -> bool:
        """True when any of ``slots`` (default: all slots in DECODE state)
        actually samples (temperature > 0); lets the engine take the jitted
        greedy fast path otherwise. A sampling request still prefilling must
        not force decoding greedy slots down the sampling branch."""
        if slots is None:
            slots = [s for s, slot in enumerate(self.slots)
                     if slot.state is SlotState.DECODE]
        return any(
            self.slots[s].req is not None
            and self.slots[s].req.sampling.temperature > 0.0
            for s in slots)

    def feed_tokens(self) -> np.ndarray:
        """(n_slots,) int32 token each slot feeds next (garbage if inactive)."""
        return np.array([s.token for s in self.slots], np.int32)

    def sampler_arrays(self):
        """Per-slot sampler params: (temperature, top_k, top_p, seed, step)."""
        n = len(self.slots)
        temp = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)
        top_p = np.ones((n,), np.float32)
        seed = np.zeros((n,), np.int32)
        step = np.zeros((n,), np.int32)
        for s, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            sp = slot.req.sampling
            temp[s], top_k[s], top_p[s] = sp.temperature, sp.top_k, sp.top_p
            seed[s], step[s] = sp.seed, slot.generated
        return temp, top_k, top_p, seed, step

    # ---- progress ----------------------------------------------------------
    def on_spec_tokens(self, s: int, tokens, n_accepted: int) -> int:
        """Deliver a speculative round's emitted tokens to slot ``s``.

        ``tokens`` is the round's ragged emission for this slot (accepted
        drafts + the correction/bonus token, in order); ``n_accepted`` counts
        the accepted drafts among them. Delivery stops when the request
        completes — surplus verified tokens are discarded (the engine's
        rewind already trimmed the cache, and a freed slot is reset
        bit-exactly on readmission anyway). Returns the delivered count.
        """
        slot = self.slots[s]
        assert slot.state is SlotState.DECODE and slot.req is not None
        slot.req.spec_accepted += int(n_accepted)
        self.spec_accepted_total += int(n_accepted)
        self.telemetry.on_spec_accept(slot.req, s, int(n_accepted))
        delivered = 0
        for t in tokens:
            delivered += 1
            if self.on_sampled(s, int(t)) is not None:
                break
        return delivered

    def on_sampled(self, s: int, token: int) -> Optional[Request]:
        """Record a sampled token for slot ``s``; returns the request when done."""
        slot = self.slots[s]
        assert slot.state is SlotState.DECODE and slot.req is not None
        slot.out.append(int(token))
        slot.token = int(token)
        slot.generated += 1
        self.telemetry.on_token(slot.req)
        if slot.generated >= slot.req.max_new_tokens:
            req = slot.req
            req.out = np.array(slot.out, np.int32)
            self.done.append(req)
            self.slots[s] = Slot()
            self.telemetry.on_complete(req)
            return req
        return None

    def busy(self) -> bool:
        return bool(self.pending) or any(
            s.state is not SlotState.FREE for s in self.slots)
