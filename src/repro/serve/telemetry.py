"""Serving telemetry: typed metrics, request-lifecycle tracing, profiling hooks.

The observability layer the adaptive-resolution arc reads from (DESIGN.md
§13). Three surfaces, one owner object (``Telemetry``, one per Engine):

  * **Typed metrics registry** — ``Counter`` / ``Gauge`` / ``Histogram`` /
    ``Series`` instances declared *at init* (``Engine.reset_stats``).
    Writing a name that was never declared raises ``UndeclaredMetric``, so
    the scheduler / SpecDecoder can no longer invent keys by dict mutation
    (the old ``Engine.stats`` ad-hoc dict). Histograms keep a *bounded*
    reservoir (a long-lived engine must not grow host memory per step) plus
    exact count/sum; gauges track their peak. ``Engine.stats`` survives as
    a compatibility ``StatsView`` over the registry.

  * **Request-lifecycle tracing** — every request carries a
    ``RequestTrace`` stamped at submit → admit → prefill-done →
    first-token → per-token → complete. The stamps feed the ttft /
    queue-wait / prefill / inter-token histograms live, and at completion
    the lifecycle is emitted as Chrome-trace begin/end span pairs
    (exportable as JSONL for chrome://tracing / Perfetto; one event object
    per line).

  * **Per-dispatch profiling hooks** — ``Telemetry.dispatch`` wraps every
    jitted entry (prefill_chunk, decode_step, draft, verify) in a
    wall-clock span + ``jax.profiler.TraceAnnotation`` tagged with kernel
    mode and cache family, so device profiles and the host trace line up.
    The jitted functions themselves carry ``jax.named_scope`` annotations
    (serve/engine.py) at zero runtime cost.

Everything is gated on ``Telemetry.enabled``: disabled, the span/stamp/
gauge paths are no-ops (``EngineConfig(telemetry=False)``) — only the
plain integer counters the engine's own bookkeeping needs keep counting.
serve_bench pins the enabled-path overhead (tok/s ratio >= 0.95, token
streams bit-identical; the clock never touches numerics).

``python -m repro.serve.telemetry`` runs the CI smoke: a snapshot must
round-trip through JSON and a recorded trace must be well-formed.
"""
from __future__ import annotations

import collections
import collections.abc
import contextlib
import dataclasses
import json
import time
from typing import Dict, Iterator, List, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RequestTrace",
    "Series",
    "StatsView",
    "Telemetry",
    "Tracer",
    "UndeclaredMetric",
    "load_trace_jsonl",
    "validate_chrome_events",
]


class UndeclaredMetric(KeyError):
    """Raised when reading/writing a metric name nobody declared at init."""


# --------------------------------------------------------------------------- #
# typed metrics
# --------------------------------------------------------------------------- #
class Counter:
    """Monotonic int counter (resettable only by re-declaring the registry)."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)


class Gauge:
    """Last-write-wins float with a high-water mark (``peak``)."""

    kind = "gauge"
    __slots__ = ("value", "peak")

    def __init__(self):
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)
        if self.value > self.peak:
            self.peak = self.value


class Histogram:
    """Bounded-reservoir distribution: exact count/sum, windowed quantiles.

    The reservoir is a ``deque(maxlen=...)`` — quantiles describe the most
    recent observations (what a serving dashboard wants), while ``count`` /
    ``total`` stay exact for the whole lifetime. This is the fix for the
    unbounded ``stats["decode_step_seconds"]`` list the old engine grew
    per decode step.
    """

    kind = "histogram"
    __slots__ = ("reservoir", "count", "total")

    def __init__(self, maxlen: int = 4096):
        self.reservoir: collections.deque = collections.deque(maxlen=maxlen)
        self.count = 0
        self.total = 0.0

    def observe(self, x: float) -> None:
        x = float(x)
        self.reservoir.append(x)
        self.count += 1
        self.total += x

    def percentile(self, q: float) -> float:
        """Reservoir quantile, ``q`` in [0, 1]; 0.0 when empty."""
        if not self.reservoir:
            return 0.0
        xs = sorted(self.reservoir)
        return xs[min(len(xs) - 1, int(len(xs) * q))]

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count if self.count else 0.0,
            "p50": self.percentile(0.5),
            "p90": self.percentile(0.9),
            "p99": self.percentile(0.99),
            "max": max(self.reservoir) if self.reservoir else 0.0,
        }


class Series:
    """Bounded per-key value series (e.g. per-slot spec acceptance)."""

    kind = "series"
    __slots__ = ("maxlen", "data")

    def __init__(self, maxlen: int = 1024):
        self.maxlen = maxlen
        self.data: Dict[str, collections.deque] = {}

    def append(self, key, v: float) -> None:
        key = str(key)
        if key not in self.data:
            self.data[key] = collections.deque(maxlen=self.maxlen)
        self.data[key].append(float(v))


class MetricsRegistry:
    """Declared-at-init metric set; undeclared names raise.

    One flat namespace (metric names are the contract, DESIGN.md §13); the
    declaring site (``Engine.reset_stats``) is the single source of truth
    for which names exist, so a typo'd or invented key fails loudly at the
    write site instead of silently forking the schema.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    # ---- declaration (init time only) -------------------------------------- #
    def _declare(self, name: str, metric):
        if name in self._metrics:
            raise ValueError(f"metric {name!r} declared twice")
        self._metrics[name] = metric
        return metric

    def declare_counter(self, *names: str) -> None:
        for n in names:
            self._declare(n, Counter())

    def declare_gauge(self, *names: str) -> None:
        for n in names:
            self._declare(n, Gauge())

    def declare_histogram(self, *names: str, maxlen: int = 4096) -> None:
        for n in names:
            self._declare(n, Histogram(maxlen=maxlen))

    def declare_series(self, *names: str, maxlen: int = 1024) -> None:
        for n in names:
            self._declare(n, Series(maxlen=maxlen))

    # ---- access ------------------------------------------------------------ #
    def get(self, name: str):
        try:
            return self._metrics[name]
        except KeyError:
            raise UndeclaredMetric(
                f"metric {name!r} was never declared; telemetry metric sets "
                "are fixed at init (Engine.reset_stats) — declare it there "
                "instead of inventing keys at the write site") from None

    def _typed(self, name: str, cls):
        m = self.get(name)
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {m.kind}, not a {cls.kind}")
        return m

    def inc(self, name: str, n: int = 1) -> None:
        self._typed(name, Counter).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self._typed(name, Gauge).set(v)

    def observe(self, name: str, x: float) -> None:
        self._typed(name, Histogram).observe(x)

    def append(self, name: str, key, v: float) -> None:
        self._typed(name, Series).append(key, v)

    def names(self) -> List[str]:
        return list(self._metrics)

    def items(self):
        return self._metrics.items()

    # ---- export ------------------------------------------------------------ #
    def snapshot(self) -> Dict[str, dict]:
        """JSON-able nested dict of every declared metric's current value."""
        out = {"counters": {}, "gauges": {}, "histograms": {}, "series": {}}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = {"value": m.value, "peak": m.peak}
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.summary()
            elif isinstance(m, Series):
                out["series"][name] = {k: list(v) for k, v in m.data.items()}
        return out

    def prometheus_text(self, prefix: str = "mra_serve_") -> str:
        """Prometheus exposition-format snapshot (counters/gauges/summaries)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            full = prefix + name
            if isinstance(m, Counter):
                lines += [f"# TYPE {full} counter", f"{full} {m.value}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {full} gauge", f"{full} {m.value:.9g}",
                          f"# TYPE {full}_peak gauge",
                          f"{full}_peak {m.peak:.9g}"]
            elif isinstance(m, Histogram):
                lines.append(f"# TYPE {full} summary")
                for q in (0.5, 0.9, 0.99):
                    lines.append(f'{full}{{quantile="{q}"}} '
                                 f"{m.percentile(q):.9g}")
                lines += [f"{full}_sum {m.total:.9g}",
                          f"{full}_count {m.count}"]
            # series are a trace-shaped surface; they export via snapshot()
        return "\n".join(lines) + "\n"


class StatsView(collections.abc.Mapping):
    """``Engine.stats`` compatibility facade over the typed registry.

    Reads return plain values (counter/gauge -> number, histogram -> the
    reservoir as a list — ``sorted(stats["decode_step_seconds"])`` keeps
    working). Writes are allowed for *declared* counters only, so the
    pre-telemetry ``stats["draft_dispatches"] += 1`` idiom still works but
    an undeclared key raises ``UndeclaredMetric`` instead of minting one.
    """

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry

    def __getitem__(self, name: str):
        m = self._registry.get(name)
        if isinstance(m, Counter):
            return m.value
        if isinstance(m, Gauge):
            return m.value
        if isinstance(m, Histogram):
            return list(m.reservoir)
        return {k: list(v) for k, v in m.data.items()}

    def __setitem__(self, name: str, value) -> None:
        m = self._registry.get(name)
        if isinstance(m, Counter):
            m.value = int(value)
        elif isinstance(m, Gauge):
            m.set(value)
        else:
            raise TypeError(
                f"{m.kind} {name!r} is observe-only; use the Telemetry API")

    def __iter__(self) -> Iterator[str]:
        return iter(self._registry.names())

    def __len__(self) -> int:
        return len(self._registry.names())


# --------------------------------------------------------------------------- #
# request-lifecycle tracing
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RequestTrace:
    """Per-request lifecycle stamps (seconds on the tracer's clock).

    ``submit -> admit -> prefill_done -> first_token -> ... -> complete``;
    ``token_times`` holds every sampled-token stamp (first included) and
    ``spec_accepts`` the per-round accepted-draft counts for this request.
    """

    submit: Optional[float] = None
    admit: Optional[float] = None
    prefill_done: Optional[float] = None
    first_token: Optional[float] = None
    complete: Optional[float] = None
    slot: Optional[int] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    spec_accepts: List[int] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None or self.submit is None:
            return None
        return self.first_token - self.submit

    @property
    def queue_wait(self) -> Optional[float]:
        if self.admit is None or self.submit is None:
            return None
        return self.admit - self.submit

    @property
    def inter_token(self) -> List[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


class Tracer:
    """Bounded Chrome-trace event buffer on a monotonic session clock."""

    def __init__(self, max_events: int = 65536):
        self._t0 = time.perf_counter()
        self.events: collections.deque = collections.deque(maxlen=max_events)

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def event(self, ph: str, name: str, ts: float, tid: int,
              args: Optional[dict] = None) -> None:
        ev = {"ph": ph, "name": name, "pid": 0, "tid": int(tid),
              "ts": round(ts * 1e6, 3)}  # Chrome trace wants microseconds
        if args:
            ev["args"] = args
        self.events.append(ev)

    def span(self, name: str, t_begin: float, t_end: float, tid: int,
             args: Optional[dict] = None) -> None:
        self.event("B", name, t_begin, tid, args)
        self.event("E", name, t_end, tid)

    def instant(self, name: str, ts: float, tid: int,
                args: Optional[dict] = None) -> None:
        ev = {"ph": "i", "name": name, "pid": 0, "tid": int(tid),
              "ts": round(ts * 1e6, 3), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts: float, tid: int, value: float) -> None:
        self.event("C", name, ts, tid, {"value": value})

    def chrome_events(self) -> List[dict]:
        """Events sorted by timestamp + thread-name metadata (valid Chrome
        trace when wrapped in a JSON array; Perfetto loads it directly)."""
        evs = sorted(self.events, key=lambda e: (e["ts"], e["ph"] != "E"))
        names = {Telemetry.ENGINE_TID: "engine dispatches"}
        meta = [{"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                 "args": {"name": names.get(tid, f"slot {tid}")}}
                for tid in sorted({e["tid"] for e in evs})]
        return meta + evs

    def export_jsonl(self, path: str) -> int:
        """Write one Chrome-trace event object per line; returns the count.

        ``load_trace_jsonl`` (or ``json.loads`` per line + wrapping in a
        JSON array) reconstructs a chrome://tracing-loadable document.
        """
        evs = self.chrome_events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev) + "\n")
        return len(evs)


def load_trace_jsonl(path: str) -> List[dict]:
    """Parse a JSONL trace back into the Chrome-trace event list."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def validate_chrome_events(events: List[dict]) -> None:
    """Assert trace well-formedness: schema, monotonic ts, matched B/E.

    Raises ``ValueError`` naming the first offending event otherwise.
    """
    stacks: Dict[int, List[str]] = {}
    last_ts = None
    for ev in events:
        missing = [k for k in ("ph", "name", "pid", "tid") if k not in ev]
        if missing:
            raise ValueError(f"trace event missing keys {missing}: {ev}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"non-metadata trace event without ts: {ev}")
        if last_ts is not None and ev["ts"] < last_ts:
            raise ValueError(
                f"trace timestamps not monotonic: {ev['ts']} after {last_ts}")
        last_ts = ev["ts"]
        if ev["ph"] == "B":
            stacks.setdefault(ev["tid"], []).append(ev["name"])
        elif ev["ph"] == "E":
            stack = stacks.setdefault(ev["tid"], [])
            if not stack:
                raise ValueError(f"unmatched end event: {ev}")
            stack.pop()
    open_spans = {tid: s for tid, s in stacks.items() if s}
    if open_spans:
        raise ValueError(f"unclosed begin events: {open_spans}")


# --------------------------------------------------------------------------- #
# the owner object
# --------------------------------------------------------------------------- #
class Telemetry:
    """One per Engine: registry + tracer + the lifecycle/dispatch helpers.

    ``enabled=False`` is the no-op fast path: lifecycle stamps, histogram
    observations, gauges, and trace events all short-circuit; counters
    (``metrics.inc``) stay live because they are the engine's own dispatch
    bookkeeping (and integer adds are far below the overhead budget).
    """

    ENGINE_TID = 1000  # trace lane for engine-level dispatch spans

    def __init__(self, enabled: bool = True, tags: Optional[dict] = None):
        self.enabled = enabled
        self.tags = dict(tags or {})
        self.metrics = MetricsRegistry()
        self.trace = Tracer()

    def now(self) -> float:
        return self.trace.now()

    # ---- per-dispatch profiling hooks -------------------------------------- #
    @contextlib.contextmanager
    def dispatch(self, name: str, hist: Optional[str] = None, **args):
        """Span one jitted dispatch: wall clock + profiler annotation.

        ``hist`` names a declared histogram to observe the duration into;
        the trace span lands on the engine lane tagged with the telemetry's
        static tags (kernel mode, cache family) + ``args``.
        """
        if not self.enabled:
            yield
            return
        import jax  # deferred so metric-only users never pay the import

        t0 = self.now()
        with jax.profiler.TraceAnnotation(f"serve.{name}"):
            yield
        t1 = self.now()
        if hist is not None:
            self.metrics.observe(hist, t1 - t0)
        self.trace.span(name, t0, t1, self.ENGINE_TID,
                        {**self.tags, **args} or None)

    # ---- request lifecycle -------------------------------------------------- #
    def on_submit(self, req) -> None:
        if self.enabled:
            req.trace = RequestTrace(submit=self.now())

    def on_admit(self, req, slot: int) -> None:
        if not (self.enabled and req.trace):
            return
        req.trace.admit = self.now()
        req.trace.slot = slot
        self.metrics.observe("queue_wait_seconds", req.trace.queue_wait)

    def on_prefill_done(self, req) -> None:
        if not (self.enabled and req.trace and req.trace.admit is not None):
            return
        req.trace.prefill_done = self.now()
        self.metrics.observe("prefill_seconds",
                             req.trace.prefill_done - req.trace.admit)

    def on_token(self, req) -> None:
        if not (self.enabled and req.trace):
            return
        t = self.now()
        tr = req.trace
        if tr.first_token is None:
            tr.first_token = t
            if tr.ttft is not None:
                self.metrics.observe("ttft_seconds", tr.ttft)
        elif tr.token_times:
            self.metrics.observe("inter_token_seconds",
                                 t - tr.token_times[-1])
        tr.token_times.append(t)

    def on_spec_accept(self, req, slot: int, n_accepted: int) -> None:
        if not self.enabled:
            return
        self.metrics.observe("spec_accepted_per_round", n_accepted)
        self.metrics.append("spec_accept_by_slot", slot, n_accepted)
        if req.trace:
            req.trace.spec_accepts.append(int(n_accepted))
        self.trace.counter("spec_accepted", self.now(), slot,
                           float(n_accepted))

    def on_complete(self, req) -> None:
        """Close the request's lifecycle and emit its trace spans."""
        if not (self.enabled and req.trace):
            return
        tr = req.trace
        tr.complete = self.now()
        if tr.slot is None:  # degenerate request: never held a slot
            return
        tid = tr.slot
        args = {"prompt_tokens": len(req.prompt),
                "new_tokens": len(tr.token_times)}
        if tr.ttft is not None:
            args["ttft_s"] = round(tr.ttft, 6)
        self.trace.span("request", tr.submit, tr.complete, tid, args)
        self.trace.span("queued", tr.submit, tr.admit, tid)
        if tr.prefill_done is not None:
            self.trace.span("prefill", tr.admit, tr.prefill_done, tid)
        if tr.first_token is not None:
            self.trace.span("decode", tr.first_token, tr.complete, tid)

    # ---- occupancy gauges --------------------------------------------------- #
    def set_occupancy(self, slot_counts: Dict[str, int],
                      cache_occ: Dict[str, float]) -> None:
        if not self.enabled:
            return
        for k, v in slot_counts.items():
            self.metrics.set_gauge(k, v)
        for k, v in cache_occ.items():
            self.metrics.set_gauge("cache_" + k, v)

    # ---- export -------------------------------------------------------------#
    def snapshot(self) -> dict:
        """Registry snapshot + static tags, JSON-round-trip safe."""
        return {"tags": dict(self.tags), **self.metrics.snapshot()}

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()


def _selftest() -> None:
    """CI smoke (scripts/ci.sh fast): JSON round-trip + trace validity."""
    tel = Telemetry(enabled=True, tags={"family": "selftest"})
    m = tel.metrics
    m.declare_counter("dispatches")
    m.declare_gauge("occupancy")
    m.declare_histogram("latency_seconds", maxlen=8)
    m.declare_series("accept_by_slot")
    m.inc("dispatches", 3)
    m.set_gauge("occupancy", 0.5)
    m.set_gauge("occupancy", 0.25)  # peak must remember 0.5
    for i in range(20):  # overflow the reservoir: stays bounded, count exact
        m.observe("latency_seconds", 0.001 * (i + 1))
    m.append("accept_by_slot", 0, 2)

    snap = tel.snapshot()
    rt = json.loads(json.dumps(snap))
    assert rt == snap, "snapshot does not round-trip through JSON"
    assert rt["counters"]["dispatches"] == 3
    assert rt["gauges"]["occupancy"] == {"value": 0.25, "peak": 0.5}
    h = rt["histograms"]["latency_seconds"]
    assert h["count"] == 20 and abs(h["sum"] - 0.21) < 1e-9
    assert len(m.get("latency_seconds").reservoir) == 8
    assert rt["series"]["accept_by_slot"] == {"0": [2.0]}

    try:
        m.inc("typo_key")
    except UndeclaredMetric:
        pass
    else:
        raise AssertionError("undeclared metric write did not raise")

    text = tel.prometheus_text()
    assert "mra_serve_dispatches 3" in text
    assert 'mra_serve_latency_seconds{quantile="0.5"}' in text

    t = tel.trace
    t0 = tel.now()
    t.instant("submit", t0, 0)
    t.span("request", t0, t0 + 0.02, 0, {"prompt_tokens": 4})
    t.span("prefill_chunk", t0 + 0.001, t0 + 0.01, Telemetry.ENGINE_TID)
    validate_chrome_events(t.chrome_events())

    import tempfile

    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as f:
        path = f.name
    n = t.export_jsonl(path)
    loaded = load_trace_jsonl(path)
    assert len(loaded) == n and all(isinstance(e, dict) for e in loaded)
    validate_chrome_events(loaded)
    print(f"[telemetry] selftest OK: snapshot round-trips, "
          f"{n} trace events well-formed")


if __name__ == "__main__":
    _selftest()
