"""Resolution-speculative decoding: coarse-pyramid draft + chunked MRA verify.

The MRA decomposition gives every serving slot a free draft model
(DESIGN.md §10): the pyramid block sums the ring-paged cache already
maintains ARE a cheap low-resolution view of the whole context. Per
speculative round, for every slot in the decode wave:

  1. snapshot — ``kv_cache.spec_snapshot`` captures the bounded ring window
     the round may destroy (O(K) per slot, never a cache copy);
  2. draft — K ordinary ``decode_step`` dispatches under the *coarse-only*
     AttentionSpec (own block exact, everything else through the pyramid
     sums; no top-m gather) autoregressively propose K tokens, writing
     draft K/V into the ring exactly like real decode;
  3. rewind — the draft's writes are rolled back (draft activations ran
     under coarse attention, so its K/V are approximations the verified
     stream must not keep);
  4. verify — ONE ``prefill_chunk`` dispatch (the PR 3 C-query path,
     unchanged) feeds [fed token, drafts] as a (K+1)-chunk: it rewrites the
     window with exact full-MRA K/V and returns the target distribution
     after every draft;
  5. accept — ``sampling.spec_verify_batch`` runs rejection sampling per
     slot (greedy degenerates to argmax-match, so greedy speculative decode
     is token-identical to the non-speculative oracle); the final
     ``spec_rewind`` trims each slot to its accepted prefix + correction
     token, replaying the kept positions' pyramid contributions bit-for-bit.

All five steps are batched across slots with ragged per-slot acceptance;
slots mid-prefill or frozen ride along untouched (``active`` masking), and
under a mesh every step runs tensor-parallel through the same shard_map
attention paths as normal serving (distributed/shard_attn.py — the spec
pytree carries ``coarse_only`` through unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model

from .sampling import draft_batch, greedy_batch, spec_verify_batch

__all__ = ["SpecDecoder", "draft_config"]


def draft_config(cfg: ModelConfig, draft_level: int = 1) -> ModelConfig:
    """The draft model IS the target model under coarse-only attention.

    ``draft_level`` > 1 coarsens the draft's background one more rung
    (DESIGN.md §14): eligible groups of 2^(draft_level-1) adjacent pages
    fold through their merged mean instead of per-page means. The grouped
    fold only exists on the jnp route, so it forces ``use_kernel`` off for
    draft dispatches (verify dispatches keep the target config untouched).
    """
    attn = cfg.attention.replace(coarse_only=True, draft_level=draft_level)
    if draft_level > 1:
        attn = attn.replace(use_kernel=False)
    return cfg.replace(attention=attn)


@functools.lru_cache(maxsize=None)
def _make_spec_fns(cfg: ModelConfig, draft_level: int = 1):
    """Jitted (draft_step, verify, accept) for a (config, draft_level).

    Cached on the (frozen, hashable) ModelConfig like the engine's own fns
    so every Engine instance shares compiled executables. None of the
    wrappers closes over the draft length K — draft steps are single-token
    and verify/accept retrace per chunk shape under jit — so engines that
    differ only in ``spec_k`` share them too. ``draft_level`` changes the
    draft dispatch's traced program, so it is part of the cache key.
    """
    model = get_model(cfg)
    dcfg = draft_config(cfg, draft_level)

    scope = f"serve.{cfg.family}.spec"  # profiler grouping (DESIGN.md §13)

    def draft_step(params, cache, tokens, active, any_sampling, temp, top_k,
                   top_p, seed, step):
        with jax.named_scope(f"{scope}.draft"):
            logits, cache = model.decode_step(params, dcfg, cache, tokens,
                                              active=active)
        # all-greedy rounds skip the sort/softmax pipeline (cf. the engine's
        # decode fast path); the greedy branch's q_probs are never read
        q, nxt = jax.lax.cond(
            any_sampling,
            lambda lg: draft_batch(lg, temp, top_k, top_p, seed, step,
                                   vocab=cfg.vocab),
            lambda lg: (jnp.zeros_like(lg, jnp.float32),
                        greedy_batch(lg, vocab=cfg.vocab)),
            logits)
        return jnp.where(active, nxt, tokens), q, cache

    def verify(params, cache, tokens, num_valid):
        with jax.named_scope(f"{scope}.verify"):
            return model.prefill_chunk(params, cfg, cache, tokens, num_valid,
                                       all_logits=True, collect_kv=True)

    def accept(logits, draft, q_probs, temp, top_k, top_p, seed, step0,
               active):
        return spec_verify_batch(logits, draft, q_probs, temp, top_k, top_p,
                                 seed, step0, active, vocab=cfg.vocab)

    return jax.jit(draft_step), jax.jit(verify), jax.jit(accept)


class SpecDecoder:
    """Drives one speculative round per engine iteration (Engine.spec_k)."""

    def __init__(self, cfg: ModelConfig, spec_k: int, draft_level: int = 1):
        if cfg.attention.kind not in ("mra2", "mra2_s"):
            raise NotImplementedError(
                "speculative decoding drafts through the MRA pyramid; "
                f"attention kind {cfg.attention.kind!r} has no coarse level")
        assert spec_k >= 1
        assert draft_level >= 1
        self.cfg = cfg
        self.k = spec_k
        self._draft, self._verify, self._accept = _make_spec_fns(
            cfg, draft_level)

    def split_wave(self, kv, active: np.ndarray):
        """(speculable, plain) split of the decode wave.

        A slot is speculable when its round window (L0, L0 + K] contains no
        ring-eviction boundary (a block start at position >= the fine
        window, ``kv.window_tokens`` — ``capacity`` is an admission limit
        and is None on H>=3 collapse-up caches): a chunked verify writes the
        whole window before attending, so a boundary strictly inside it
        would evict (or at H>=3 collapse) a block that the window's earlier
        queries still see in the oracle. A boundary exactly AT L0 is fine —
        the fed token's write evicts it for every query, same as the
        oracle. Affected slots take plain decode steps instead: up to K
        consecutive waves approaching each block crossing (~K/block of
        post-window tokens), until the boundary sits at the window start.
        Shrinking the draft window to the boundary instead (ragged per-slot
        K) would keep those waves speculative — ROADMAP open item.
        """
        L0 = kv.lengths
        last_boundary = (L0 + self.k) // kv.block * kv.block
        unsafe = (last_boundary > L0) & (last_boundary >= kv.window_tokens)
        return active & ~unsafe, active & unsafe

    def round(self, engine, sched, active: np.ndarray) -> None:
        """One batched draft(K) -> rewind -> verify -> accept -> trim round.

        ``active`` is the decode wave mask; inactive slots' state is
        preserved bit-for-bit through every dispatch.
        """
        K = self.k
        kv = engine.kv
        tel = engine.telemetry
        snap = kv.spec_snapshot(K + 1)
        act = jnp.asarray(active)
        fed = jnp.asarray(sched.feed_tokens())
        temp, top_k, top_p, seed, step0 = map(jnp.asarray,
                                              sched.sampler_arrays())
        any_s = jnp.asarray(sched.any_sampling())

        tok, drafts, qs = fed, [], []
        for j in range(K):
            with tel.dispatch("draft", hist="draft_seconds", step=j):
                tok, q, kv.tree = self._draft(
                    engine.params, kv.tree, tok, act, any_s, temp, top_k,
                    top_p, seed, step0 + j)
            drafts.append(tok)
            qs.append(q)
            tel.metrics.inc("draft_dispatches")
        # roll the draft's approximate writes back before the exact rewrite
        kv.spec_rewind(snap, snap["lengths"], act)

        chunk = jnp.stack([fed] + drafts, axis=1)  # (B, K+1)
        num_valid = jnp.where(act, K + 1, 0).astype(jnp.int32)
        with tel.dispatch("verify", hist="verify_seconds", k=K):
            logits, kv.tree, chunk_kv = self._verify(
                engine.params, kv.tree, chunk, num_valid)
        tel.metrics.inc("verify_dispatches")

        out, n_out, n_acc = self._accept(
            logits, jnp.stack(drafts, axis=1), jnp.stack(qs, axis=1),
            temp, top_k, top_p, seed, step0, act)
        # trim each slot to accepted prefix + correction/bonus token: the
        # last emitted token is never fed, so the kept stream is L0 + n_out
        kv.spec_rewind(snap, snap["lengths"] + n_out, act, chunk_kv)

        out, n_out, n_acc = map(np.asarray, (out, n_out, n_acc))
        emitted = 0
        for s in np.flatnonzero(active):
            emitted += sched.on_spec_tokens(
                int(s), out[s, : n_out[s]], int(n_acc[s]))
        m = tel.metrics
        m.inc("generated_tokens", emitted)
        m.inc("spec_rounds")
        m.inc("spec_drafted_tokens", int(K * active.sum()))
        m.inc("spec_accepted_tokens", int(n_acc[active].sum()))
        # delivered to requests (surplus past max_new_tokens is discarded)
        m.inc("spec_emitted_tokens", emitted)
