"""Serving engine: ragged continuous batching with chunked prefill + sampling.

The production-serving loop (DESIGN.md §9), lifted above the cache type by
the per-layer cache protocol (serve/cache/, DESIGN.md §12): the engine
resolves every model through one uniform registry contract —
``cache_specs / layer_cache_kinds / prefill_chunk / decode_step`` — so the
paged-pyramid transformer families, the RWKV-6 recurrent family, and the
hybrid local/rglru recurrentgemma family all serve through the same loop.
Per engine iteration:

  1. admission — pending requests bind to FREE slots; the slot's cache rows
     are reset bit-exactly (cache.CacheBackend.reset_slots).
  2. chunked prefill — ONE jitted ``prefill_chunk`` dispatch advances every
     PREFILL slot by up to ``chunk`` prompt tokens (ragged ``num_valid``),
     writing KV + pyramid block sums (paged), wkv states (recurrent), or
     window rings + RG-LRU states (hybrid) directly. O(ceil(P/chunk))
     dispatches per prompt instead of the O(P) per-token decode replays of
     the old engine. Slots whose prompt completes sample their first token
     from the chunk's last-position logits.
  3. decode — ONE jitted ``decode_step`` + fused ``sample_batch`` dispatch
     advances every DECODE slot (active-masked: other slots' state is
     untouched bit-for-bit), each at its own ragged length. With
     ``spec_k > 0`` the decode wave instead runs a resolution-speculative
     round (serve/speculative.py, DESIGN.md §10): K coarse-pyramid draft
     steps + one chunked full-MRA verify dispatch emit up to K+1 tokens per
     slot, with rejection sampling keeping output distributions — and greedy
     outputs bit — identical to this non-speculative path. Speculation needs
     the paged backend (pyramid draft + ring rewind).

Slots never wait for each other: a slot can decode while its neighbor is
mid-prefill, and finished slots readmit immediately. With ``mesh`` set the
engine serves tensor-parallel (params/cache placed by ParamSpec axes;
attention through shard_map when ``cfg.attn_shard``).

Observability (serve/telemetry.py, DESIGN.md §13): every engine owns a
``Telemetry`` whose metric set is declared in ``reset_stats`` — typed
counters for dispatches/tokens, bounded histograms for prefill-chunk /
decode-step / draft / verify wall time and the request-derived TTFT /
queue-wait / inter-token latencies, gauges for scheduler slot occupancy and
cache page/eviction occupancy, and a Chrome-trace request lifecycle.
``Engine.stats`` survives as a typed view over the registry (undeclared
keys raise). ``EngineConfig(telemetry=False)`` is the pinned no-op path.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import mesh_utils
from repro.models import get_model

from .cache import make_cache
from .sampling import SamplingParams, greedy_batch, sample_batch
from .scheduler import Request, Scheduler, SlotState
from .telemetry import StatsView, Telemetry

__all__ = ["Engine", "EngineConfig", "Request", "SamplingParams"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine construction knobs (the old ``Engine(**kwargs)`` sprawl).

    slots: concurrent sequences served.
    max_len: per-slot cache window. For MRA attention this is the ring
      capacity (must divide into pyramid blocks): prompts must fit, but
      generation beyond it evicts the oldest background pages. For dense
      attention kinds it is a hard prompt+generation cap. The recurrent /
      sliding-window backends hold O(1)/O(window) state per slot, so for
      them it only sizes the window ring (no admission cap).
    chunk: prefill chunk size (tokens per slot per prefill dispatch);
      clamped to ``max_len`` and to the backend's ``chunk_cap`` (a window
      ring absorbs at most W tokens per dispatch).
    spec_k: speculative draft length (0 = plain decode); requires an MRA
      attention kind and the paged cache backend, and ``spec_k + 1 <=
      max_len``.
    draft_level: resolution the speculative draft reads the background at
      (DESIGN.md §14). 1 (default) is the per-page coarse draft; level d > 1
      folds groups of 2^(d-1) adjacent pages through their merged mean —
      cheaper draft attention, unchanged output distribution (verify is
      always full-MRA). Requires ``decode_blocks % 2^(d-1) == 0``.
    mesh: jax device mesh for tensor-parallel serving (None = single device).
    default_sampling: sampler settings applied to requests submitted with
      ``sampling=None`` (None = greedy).
    kernel_mode: fused-serving-kernel tile shape when ``cfg.attn_use_kernel``
      (kernels/chunk_attn.py, DESIGN.md §11). "auto" (default) is the
      per-dispatch pick: decode waves trace with C == 1 and run the
      ``latency`` instantiation (single-query tiles, one wave per
      batch·kv-head), while chunked prefill and speculative verify trace
      with C == chunk / spec_k + 1 and run ``throughput`` (multi-query MXU
      tiles). "latency" / "throughput" force one tile shape for every
      dispatch — token streams are bit-identical in all three settings
      (tests/test_chunk_kernel.py pins it); only the tiling changes.
    telemetry: enable the full observability path — request-lifecycle
      tracing, latency histograms, occupancy gauges, profiler annotations
      (serve/telemetry.py, DESIGN.md §13). ``False`` is the no-op fast
      path: only the plain dispatch/token counters keep counting; token
      streams are bit-identical either way and serve_bench pins the
      enabled-path overhead at tok/s ratio >= 0.95.
    """

    slots: int = 4
    max_len: int = 512
    chunk: int = 32
    spec_k: int = 0
    draft_level: int = 1
    mesh: Optional[object] = None
    default_sampling: Optional[SamplingParams] = None
    kernel_mode: str = "auto"
    telemetry: bool = True

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@functools.lru_cache(maxsize=None)
def _make_engine_fns(cfg: ModelConfig):
    """Jitted (prefill_chunk, decode+sample, sample) for a config.

    Cached on the (frozen, hashable) ModelConfig so every Engine instance for
    the same config shares compiled executables.
    """
    model = get_model(cfg)
    missing = [name for name in
               ("cache_specs", "layer_cache_kinds", "prefill_chunk",
                "decode_step")
               if not hasattr(model, name)]
    if missing:
        raise NotImplementedError(
            f"family {cfg.family!r} does not implement the serving contract "
            f"(missing {missing}; see models/registry.py)")

    # trace-time profiler annotations (zero runtime cost): device profiles
    # group each serving entry point by family + kernel mode (DESIGN.md §13)
    scope = f"serve.{cfg.family}.{cfg.attn_kernel_mode}"

    def prefill_chunk(params, cache, tokens, num_valid):
        with jax.named_scope(f"{scope}.prefill_chunk"):
            return model.prefill_chunk(params, cfg, cache, tokens, num_valid)

    def decode_and_sample(params, cache, tokens, active, any_sampling, temp,
                          top_k, top_p, seed, step):
        with jax.named_scope(f"{scope}.decode_step"):
            logits, cache = model.decode_step(params, cfg, cache, tokens,
                                              active=active)
        # all-greedy batches (the common case) skip the sort/softmax/cumsum
        # sampling pipeline entirely; greedy_batch is sample_batch's own
        # temperature == 0 path, so the token is identical either way
        nxt = jax.lax.cond(
            any_sampling,
            lambda lg: sample_batch(lg, temp, top_k, top_p, seed, step,
                                    vocab=cfg.vocab),
            lambda lg: greedy_batch(lg, vocab=cfg.vocab),
            logits)
        return jnp.where(active, nxt, tokens), cache

    def sample_only(logits, any_sampling, temp, top_k, top_p, seed, step):
        return jax.lax.cond(
            any_sampling,
            lambda lg: sample_batch(lg, temp, top_k, top_p, seed, step,
                                    vocab=cfg.vocab),
            lambda lg: greedy_batch(lg, vocab=cfg.vocab),
            logits)

    return jax.jit(prefill_chunk), jax.jit(decode_and_sample), jax.jit(sample_only)


class Engine:
    """Batched request server over ``config.slots`` concurrent sequences.

    Construction: ``Engine(cfg, params, EngineConfig(...))``. The pre-
    EngineConfig keyword signature (``Engine(cfg, params, slots=...,
    max_len=..., chunk=..., spec_k=..., mesh=...)``) survives as a
    deprecated shim for one release and warns on use.

    Serves every registered family through the uniform contract: the cache
    backend is selected from the model's per-layer cache kinds
    (serve/cache.make_cache), chunked prefill goes through the family's
    ``prefill_chunk`` (paged KV scatter, chunked wkv, or chunked
    window/RG-LRU), and decode through its active-masked ``decode_step``.
    """

    def __init__(self, cfg: ModelConfig, params,
                 config: Optional[EngineConfig] = None, **kwargs):
        if kwargs:
            known = {f.name for f in dataclasses.fields(EngineConfig)}
            unknown = set(kwargs) - known
            if unknown:
                raise TypeError(
                    f"Engine() got unexpected keyword arguments {sorted(unknown)}")
            warnings.warn(
                "Engine(cfg, params, slots=..., max_len=..., ...) is "
                "deprecated; pass an EngineConfig instead",
                DeprecationWarning, stacklevel=2)
            config = dataclasses.replace(config or EngineConfig(), **kwargs)
        elif config is None:
            config = EngineConfig()
        if config.kernel_mode not in ("auto", "latency", "throughput"):
            raise ValueError(
                "EngineConfig.kernel_mode must be 'auto' | 'latency' | "
                f"'throughput', got {config.kernel_mode!r}")
        if config.kernel_mode != "auto":
            # forced mode rides the (frozen, hashable) ModelConfig into
            # _make_engine_fns, so each forced mode compiles its own
            # executables; "auto" resolves per entry point at trace time
            cfg = cfg.replace(attn_kernel_mode=config.kernel_mode)
        self.config = config
        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = config.slots
        self.max_len = config.max_len
        self.spec_k = config.spec_k
        self.mesh = config.mesh
        self.kv = make_cache(cfg, self.model, self.slots, self.max_len,
                             mesh=self.mesh)
        self.chunk = min(config.chunk, self.max_len)
        if self.kv.chunk_cap is not None:
            self.chunk = min(self.chunk, self.kv.chunk_cap)
        self._spec = None
        if self.spec_k:
            from .speculative import SpecDecoder

            if self.spec_k + 1 > self.max_len:
                raise ValueError(
                    f"spec_k {self.spec_k} + 1 exceeds the cache window "
                    f"{self.max_len}")
            self._spec = SpecDecoder(cfg, self.spec_k,
                                     draft_level=config.draft_level)
            if not self.kv.supports_spec:
                raise NotImplementedError(
                    "speculative decoding needs the ring-paged MRA cache "
                    f"backend; {type(self.kv).__name__} has no "
                    "snapshot/rewind (DESIGN.md §12)")
        if self.mesh is not None:
            from repro.models.params import param_shardings

            params = jax.tree.map(
                jax.device_put, params,
                param_shardings(self.model.param_specs(cfg), self.mesh))
        self.params = params
        self._prefill, self._decode, self._sample = _make_engine_fns(cfg)
        self.reset_stats()

    def reset_stats(self) -> None:
        """Re-declare the engine's full metric set, zeroed (DESIGN.md §13).

        This is the *only* place serving metrics come into existence: every
        counter any component ever writes — the engine's own dispatch/token
        counters AND the speculative keys SpecDecoder increments
        (``draft_dispatches``, ``spec_rounds``, …) — is declared here, so a
        write to an undeclared name raises ``UndeclaredMetric`` at the
        write site instead of silently minting a new key.
        """
        tel = Telemetry(enabled=self.config.telemetry, tags={
            "family": self.cfg.family,
            "cache": type(self.kv).__name__,
            "kernel_mode": self.config.kernel_mode,
        })
        m = tel.metrics
        m.declare_counter(
            "prefill_dispatches", "decode_dispatches", "prefill_tokens",
            "generated_tokens", "requests_completed",
            # speculative decoding (spec_k > 0; serve/speculative.py)
            "spec_rounds", "draft_dispatches", "verify_dispatches",
            "spec_drafted_tokens", "spec_accepted_tokens",
            "spec_emitted_tokens")
        # dispatch wall time + request-derived latencies; bounded reservoirs
        # (a long-lived engine must not grow host memory per step)
        m.declare_histogram(
            "decode_step_seconds", "prefill_chunk_seconds", "draft_seconds",
            "verify_seconds", "ttft_seconds", "queue_wait_seconds",
            "prefill_seconds", "inter_token_seconds",
            "spec_accepted_per_round")
        # occupancy gauges, refreshed once per engine iteration; the cache
        # keys come from the backend itself (set_occupancy prefixes them
        # with "cache_") so backends with extra gauges — e.g. the H-level
        # cache's per-level entry/token counts (DESIGN.md §14) — declare
        # them without the engine enumerating every backend's set
        m.declare_gauge(
            "queue_depth", "slots_free", "slots_prefill", "slots_decode",
            *("cache_" + k for k in self.kv.occupancy()))
        m.declare_series("spec_accept_by_slot")
        self.telemetry = tel

    @property
    def stats(self) -> StatsView:
        """Typed view over the telemetry registry (legacy ``stats`` dict
        shape: counters read/write as ints, ``decode_step_seconds`` reads as
        the reservoir list; undeclared keys raise)."""
        return StatsView(self.telemetry.metrics)

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion; returns them with ``out`` filled
        (completion order, which may differ from submission order)."""
        sched = Scheduler(self.slots, self.kv.capacity, self.chunk,
                          ring=self.kv.paged,
                          default_sampling=self.config.default_sampling,
                          telemetry=self.telemetry)
        for r in requests:
            sched.submit(r)
        with mesh_utils.use_mesh(self.mesh):
            while sched.busy():
                self._iterate(sched)
        self.telemetry.metrics.inc("requests_completed", len(sched.done))
        return sched.done

    # ------------------------------------------------------------------ #
    def _iterate(self, sched: Scheduler) -> None:
        tel = self.telemetry
        newly = sched.admit()
        if newly:
            mask = np.zeros((self.slots,), bool)
            mask[newly] = True
            self.kv.reset_slots(mask)

        plan = sched.prefill_plan()
        if plan is not None:
            tokens, num_valid, finishing = plan
            # satellite of §13: prefill dispatches are timed like decode
            # steps, so TTFT decomposes into queue + prefill + first-decode
            with tel.dispatch("prefill_chunk", hist="prefill_chunk_seconds",
                              tokens=int(num_valid.sum())):
                logits, self.kv.tree = self._prefill(
                    self.params, self.kv.tree, jnp.asarray(tokens),
                    jnp.asarray(num_valid))
                if finishing:
                    first = self._sample(
                        logits, jnp.asarray(sched.any_sampling(finishing)),
                        *map(jnp.asarray, sched.sampler_arrays()))
                    first = np.asarray(first)
            tel.metrics.inc("prefill_dispatches")
            tel.metrics.inc("prefill_tokens", int(num_valid.sum()))
            if finishing:
                for s in finishing:
                    tel.on_prefill_done(sched.slots[s].req)
                    sched.on_sampled(s, first[s])
                tel.metrics.inc("generated_tokens", len(finishing))

        active = sched.decode_mask()
        if active.any():
            t0 = tel.now() if tel.enabled else 0.0
            if self._spec is not None:
                # slots whose round window straddles a ring-eviction boundary
                # take a plain decode step instead (a chunked verify would
                # evict a block that its earlier queries must still see; the
                # oracle evicts it only when the boundary token is written) —
                # up to spec_k waves approaching each block crossing.
                spec_wave, plain_wave = self._spec.split_wave(self.kv, active)
                if spec_wave.any():
                    self._spec.round(self, sched, spec_wave)
                if plain_wave.any():
                    self._plain_decode(sched, plain_wave)
            else:
                self._plain_decode(sched, active)
            if tel.enabled:
                tel.metrics.observe("decode_step_seconds", tel.now() - t0)
        if tel.enabled:
            states = [s.state for s in sched.slots]
            tel.set_occupancy(
                {"queue_depth": len(sched.pending),
                 "slots_free": states.count(SlotState.FREE),
                 "slots_prefill": states.count(SlotState.PREFILL),
                 "slots_decode": states.count(SlotState.DECODE)},
                self.kv.occupancy())

    def _plain_decode(self, sched: Scheduler, active: np.ndarray) -> None:
        """One fused decode_step + sample dispatch for the ``active`` slots."""
        feed = sched.feed_tokens()
        temp, top_k, top_p, seed, step = sched.sampler_arrays()
        with self.telemetry.dispatch("decode_step",
                                     slots=int(active.sum())):
            nxt, self.kv.tree = self._decode(
                self.params, self.kv.tree, jnp.asarray(feed),
                jnp.asarray(active), jnp.asarray(sched.any_sampling()),
                jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p),
                jnp.asarray(seed), jnp.asarray(step))
            nxt = np.asarray(nxt)
        self.telemetry.metrics.inc("decode_dispatches")
        for s in np.flatnonzero(active):
            sched.on_sampled(int(s), nxt[s])
        self.telemetry.metrics.inc("generated_tokens", int(active.sum()))
