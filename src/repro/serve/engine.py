"""Serving engine: prefill + decode with slot-based continuous batching.

``serve_step`` (one decode step for a full batch of active slots) is the
function the decode-shape dry-runs lower. The Engine wraps it with a simple
continuous-batching scheduler: fixed number of slots, finished sequences are
replaced from the pending queue between steps — the standard
production-serving shape (vLLM-style, without paged attention since the MRA
pyramid gives us block-granular access already).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import mesh_utils
from repro.models import get_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


def make_serve_step(cfg: ModelConfig):
    model = get_model(cfg)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cfg, cache, tokens)

    return serve_step


def make_prefill(cfg: ModelConfig):
    model = get_model(cfg)

    def prefill(params, batch, cache):
        return model.prefill(params, cfg, batch, cache)

    return prefill


class Engine:
    """Batched request server over ``slots`` concurrent sequences.

    With ``mesh`` set, the engine serves tensor-parallel: parameters and the
    decode state (KV cache, pyramid block sums, dequant scales) are placed by
    their ParamSpec logical axes — batch/slots over the data axes, kv-heads
    over the model axis — and the decode step runs under the mesh so
    ``cfg.attn_shard`` routes attention through shard_map (DESIGN.md §8).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, mesh=None):
        from repro.models.params import init_params as build

        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.mesh = mesh
        cache_specs = self.model.cache_specs(cfg, slots, max_len)
        self.cache = build(cache_specs, jax.random.PRNGKey(0))  # zeros-init specs
        if mesh is not None:
            from repro.models.params import param_shardings

            params = jax.tree.map(
                jax.device_put, params,
                param_shardings(self.model.param_specs(cfg), mesh),
            )
            self.cache = jax.tree.map(
                jax.device_put, self.cache, param_shardings(cache_specs, mesh)
            )
        self.params = params
        self._decode = jax.jit(make_serve_step(cfg))
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots,), np.int32)
        self.remaining = np.zeros((slots,), np.int64)

    def _step(self, tokens):
        """One jitted decode step under the engine's mesh (if any)."""
        with mesh_utils.use_mesh(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, tokens)
        return logits

    def _prefill_one(self, slot: int, req: Request):
        """Sequential per-slot prefill via decode steps (simple & correct)."""
        toks = req.prompt.astype(np.int32)
        logits = None
        for t in toks:
            batch_tok = jnp.asarray(self.tokens)
            batch_tok = batch_tok.at[slot].set(int(t))
            logits = self._step(batch_tok)
        if logits is not None:
            self.tokens[slot] = int(jnp.argmax(logits[slot]))
        # empty prompt: keep the slot's current token as the seed
        req.out = np.array([], np.int32)
        self.remaining[slot] = req.max_new_tokens

    def run(self, requests: List[Request], *, greedy: bool = True):
        """Process all requests; returns the list with ``out`` filled."""
        pending = list(requests)
        done: List[Request] = []
        # NOTE: per-slot prefill here advances the *whole* batch cache; for the
        # framework's purposes (tests/examples) slots are filled one wave at a
        # time so lengths stay aligned per wave.
        while pending or any(a is not None for a in self.active):
            for s in range(self.slots):
                if self.active[s] is None and pending:
                    req = pending.pop(0)
                    self.active[s] = req
                    self._prefill_one(s, req)
            logits = self._step(jnp.asarray(self.tokens))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in range(self.slots):
                req = self.active[s]
                if req is None:
                    continue
                req.out = np.append(req.out, self.tokens[s])
                self.tokens[s] = nxt[s]
                self.remaining[s] -= 1
                if self.remaining[s] <= 0:
                    done.append(req)
                    self.active[s] = None
        return done
