"""Ring-paged KV cache manager for the serving engine.

The cache is block-granular: physical pages are ``cfg.attention.block_size``
tokens, i.e. exactly the MRA pyramid's blocks — the pyramid block sums ARE
the page table payload (one (B, nb) int32 table of logical block owners,
shared by every layer, plus per-layer k/v/pyr tensors declared by
``model.cache_specs``). Position ``p`` of a slot lives at physical index
``p % capacity``; once a slot's stream exceeds the capacity, appending
recycles the oldest background page (ring eviction) while
``mra2_decode_attention`` keeps selecting its top-m blocks among the live
pages. Non-MRA attention kinds get the same storage without a page table
(dense, hard capacity).

This module owns the engine-side lifecycle: building/placing the cache tree,
bit-exact per-slot reset on admission, and occupancy introspection. The
ring/page *math* lives with the attention code (core/mra_decode.py) so the
model layer never imports serve/.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.mra_decode import quantize_kv  # re-export: page quantization
from repro.models.params import init_params, param_shardings

__all__ = ["RingPagedKVCache", "quantize_kv"]


@functools.lru_cache(maxsize=None)
def _make_reset(paged: bool):
    """Jitted bit-exact slot reset: zero the rows selected by ``mask``.

    Only the *validity* state is cleared (lengths, page table, pyramid block
    sums); stale K/V bytes are unreachable once no live page maps to them, so
    they are left in place — same trick as the dense path's length masking.
    """

    def reset(cache, mask):
        c = dict(cache)
        c["lengths"] = jnp.where(mask, 0, cache["lengths"])
        if paged:
            c["page_blocks"] = jnp.where(
                mask[:, None], jnp.int32(-1), cache["page_blocks"])
        if "pyr_k" in c:
            m4 = mask[:, None, None, None]
            c["pyr_k"] = [jnp.where(m4, 0.0, a) for a in cache["pyr_k"]]
            c["pyr_v"] = [jnp.where(m4, 0.0, a) for a in cache["pyr_v"]]
        return c

    return jax.jit(reset)


class RingPagedKVCache:
    """Engine-side decode state: KV pages + pyramid + page table + lengths.

    With ``mesh`` set, every tensor is placed by its ParamSpec logical axes
    (slots over the data axes, kv-heads over the model axis) so the decode
    and chunked-prefill steps run tensor-parallel (DESIGN.md §8/§9).
    """

    def __init__(self, cfg: ModelConfig, model, slots: int, max_len: int,
                 mesh=None):
        if cfg.attention.kind in ("mra2", "mra2_s"):
            if max_len % cfg.attention.block_size != 0:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of the MRA block "
                    f"size {cfg.attention.block_size} (pages are blocks)")
        self.cfg = cfg
        self.slots = slots
        self.capacity = max_len
        self.specs = model.cache_specs(cfg, slots, max_len)
        self.paged = "page_blocks" in self.specs
        self.block = cfg.attention.block_size if self.paged else None
        self.pages = max_len // cfg.attention.block_size if self.paged else None
        self.quantized = "k_scale" in self.specs
        self.tree = init_params(self.specs, jax.random.PRNGKey(0))
        if mesh is not None:
            self.tree = jax.tree.map(
                jax.device_put, self.tree, param_shardings(self.specs, mesh))
        self._reset = _make_reset(self.paged)

    def reset_slots(self, mask: np.ndarray):
        """Clear the slots selected by ``mask`` (B,) bool for re-admission."""
        self.tree = self._reset(self.tree, jnp.asarray(mask))

    @property
    def lengths(self) -> np.ndarray:
        return np.asarray(self.tree["lengths"])

    def live_pages(self) -> Optional[np.ndarray]:
        """(B,) live (non-evicted) page count per slot; None when dense."""
        if not self.paged:
            return None
        return np.asarray((np.asarray(self.tree["page_blocks"]) >= 0).sum(-1))

    def window_start(self) -> np.ndarray:
        """(B,) oldest position still attendable (0 until eviction kicks in)."""
        if not self.paged:
            return np.zeros((self.slots,), np.int64)
        pb = np.asarray(self.tree["page_blocks"]).astype(np.int64)
        oldest = np.where(pb >= 0, pb, np.iinfo(np.int64).max).min(-1)
        oldest = np.where((pb >= 0).any(-1), oldest, 0)
        return oldest * self.block
