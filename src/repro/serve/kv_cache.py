"""Compatibility shim — the cache moved to serve/cache/ (protocol + backends).

The ring-paged MRA cache now lives in serve/cache/paged.py as one backend of
the per-layer cache protocol (serve/cache/protocol.py, DESIGN.md §12),
alongside the recurrent-state and hybrid sliding-window backends. Import
from ``repro.serve.cache`` going forward; this module re-exports the old
names so existing callers keep working.
"""
from __future__ import annotations

import warnings

from .cache.paged import RingPagedKVCache, quantize_kv

warnings.warn(
    "repro.serve.kv_cache is deprecated; import RingPagedKVCache / "
    "quantize_kv from repro.serve.cache instead (DESIGN.md §12)",
    DeprecationWarning, stacklevel=2)

__all__ = ["RingPagedKVCache", "quantize_kv"]
