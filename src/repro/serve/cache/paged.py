"""Ring-paged KV cache backend for the serving engine.

The cache is block-granular: physical pages are ``cfg.attention.block_size``
tokens, i.e. exactly the MRA pyramid's blocks — the pyramid block sums ARE
the page table payload (one (B, nb) int32 table of logical block owners,
shared by every layer, plus per-layer k/v/pyr tensors declared by
``model.cache_specs``). Position ``p`` of a slot lives at physical index
``p % capacity``; once a slot's stream exceeds the capacity, appending
recycles the oldest background page (ring eviction) while
``mra2_decode_attention`` keeps selecting its top-m blocks among the live
pages. Non-MRA attention kinds get the same storage without a page table
(dense, hard capacity).

This module owns the engine-side lifecycle: building/placing the cache tree,
bit-exact per-slot reset on admission, and occupancy introspection. The
ring/page *math* lives with the attention code (core/mra_decode.py) so the
model layer never imports serve/.

Speculative decoding (DESIGN.md §10) adds the *bounded ring rewind*: before
a draft round, ``spec_snapshot`` captures exactly the state a W-token write
window can destroy — the W physical K/V rows starting at each slot's length
(a ring page being recycled overwrites the evicted block's bytes with the
new block's), plus references to the (immutable, small) lengths / page table
/ pyramid arrays. ``spec_rewind`` then restores any per-slot target length
in [L0, L0+W]: lengths and window bytes at positions >= target come back
from the snapshot, page ownership created by writes at positions >= target
is undone, and the pyramid is rebuilt as snapshot + the accepted prefix's
exact fp32 contributions (replayed from the verify chunk's K/V, not from
possibly-quantized cache bytes). Cost is O(W) per slot per round,
independent of the stream length — speculation never copies the cache.

H-level hierarchy (``cfg.attention.levels >= 3``, core/hier.py, DESIGN.md
§14): ring eviction becomes *collapse-up* — a recycled page's pyramid sums
merge into coarser per-level entry rings (int8 near, int4-precision far)
and ultimately a fp32 tail, so the cache serves contexts far longer than
its fine window from bounded memory. ``capacity`` is then None (admission
unbounded; long prompts stream through chunked prefill, collapsing as they
go), ``window_tokens`` keeps the fine-window size for the speculative
boundary rule, ``occupancy()`` grows per-level gauges, and the snapshot/
rewind pair restores collapsed sums exactly (wholesale restore + replay of
the kept writes' collapses).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.mra_decode import quantize_kv  # re-export: page quantization
from repro.models.params import init_params, param_shardings

from .protocol import CacheBackend

__all__ = ["RingPagedKVCache", "quantize_kv"]


@functools.lru_cache(maxsize=None)
def _make_reset(paged: bool, hier_lids: tuple = ()):
    """Jitted bit-exact slot reset: zero the rows selected by ``mask``.

    Only the *validity* state is cleared (lengths, page table, pyramid block
    sums, and — when hierarchical, DESIGN.md §14 — the collapsed-level
    owner/count tables and the fp32 tail); stale K/V bytes and stale
    collapsed-entry payloads/scales are unreachable once no live page /
    entry count points at them, so they are left in place — same trick as
    the dense path's length masking.
    """

    def reset(cache, mask):
        c = dict(cache)
        c["lengths"] = jnp.where(mask, 0, cache["lengths"])
        if paged:
            c["page_blocks"] = jnp.where(
                mask[:, None], jnp.int32(-1), cache["page_blocks"])
        if "pyr_k" in c:
            m4 = mask[:, None, None, None]
            c["pyr_k"] = [jnp.where(m4, 0.0, a) for a in cache["pyr_k"]]
            c["pyr_v"] = [jnp.where(m4, 0.0, a) for a in cache["pyr_v"]]
        for lvl in hier_lids:
            c[f"hier_own{lvl}"] = jnp.where(
                mask[:, None], jnp.int32(-1), cache[f"hier_own{lvl}"])
            c[f"hier_cnt{lvl}"] = jnp.where(
                mask[:, None], 0, cache[f"hier_cnt{lvl}"])
        if hier_lids:
            m3 = mask[:, None, None]
            c["tail_k"] = [jnp.where(m3, 0.0, a) for a in cache["tail_k"]]
            c["tail_v"] = [jnp.where(m3, 0.0, a) for a in cache["tail_v"]]
            c["tail_cnt"] = jnp.where(mask, 0, cache["tail_cnt"])
        return c

    return jax.jit(reset)


def _window_indices(lengths, W: int, S: int):
    """((B, W) global positions, (B, W) physical ring indices, (B, W) b_idx)."""
    B = lengths.shape[0]
    pos = lengths[:, None] + jnp.arange(W, dtype=lengths.dtype)  # (B, W)
    b2 = jnp.broadcast_to(jnp.arange(B)[:, None], (B, W))
    return pos, pos % S, b2


@functools.lru_cache(maxsize=None)
def _make_spec_fns(W: int, block: int, quant: bool, hier_lids: tuple = ()):
    """Jitted (window gather, ring rewind) for a W-token speculative window.

    Cached on the static window shape; the cache tree itself rides through
    as a pytree argument so every Engine/config shares compiled code per W.
    ``hier_lids`` names the collapsed levels of an H-level cache (§14): the
    rewind then also restores the hierarchy to its snapshot exactly and
    replays the collapses that the *kept* writes performed.
    """

    def gather(cache):
        S = cache["k"][0].shape[2]
        _, widx, b2 = _window_indices(cache["lengths"], W, S)
        win = {
            "k": [k[b2, :, widx] for k in cache["k"]],  # (B, W, Hkv, D)
            "v": [v[b2, :, widx] for v in cache["v"]],
        }
        if quant:
            win["k_scale"] = [s[b2, :, widx] for s in cache["k_scale"]]
            win["v_scale"] = [s[b2, :, widx] for s in cache["v_scale"]]
        return win

    def rewind(cache, snap, target_lengths, gate, chunk_kv):
        """Restore every ``gate`` slot to ``target_lengths`` in [L0, L0+W].

        Slots with ``gate`` False — or already at their target — keep every
        byte of their state untouched. ``chunk_kv`` is (chunk_k, chunk_v)
        from the verify dispatch ((L, B, Hkv, C, D) fp32, C <= W) whose
        position-p entries are replayed into the pyramid for L0 <= p < Lt;
        None means no replay (the pure post-draft rewind, Lt == L0).
        """
        L0 = snap["lengths"]
        Lt = target_lengths.astype(L0.dtype)
        cur = cache["lengths"]
        need = gate & (Lt < cur)
        c = dict(cache)
        c["lengths"] = jnp.where(need, Lt, cur)
        S = cache["k"][0].shape[2]
        pos, widx, b2 = _window_indices(L0, W, S)
        restore = need[:, None] & (pos >= Lt[:, None])  # (B, W)
        r4 = restore[:, :, None, None]
        r3 = restore[:, :, None]

        def put(arr, saved, m):
            old = arr[b2, :, widx]
            return arr.at[b2, :, widx].set(jnp.where(m, saved, old))

        c["k"] = [put(a, s, r4) for a, s in zip(cache["k"], snap["win"]["k"])]
        c["v"] = [put(a, s, r4) for a, s in zip(cache["v"], snap["win"]["v"])]
        if quant:
            c["k_scale"] = [put(a, s, r3) for a, s in
                            zip(cache["k_scale"], snap["win"]["k_scale"])]
            c["v_scale"] = [put(a, s, r3) for a, s in
                            zip(cache["v_scale"], snap["win"]["v_scale"])]
        # page ownership created by a write at position >= Lt is undone; an
        # owner whose block starts below Lt legitimately exists at Lt (it is
        # at worst partial), including blocks first opened by kept writes.
        pb = cache["page_blocks"]
        undo = need[:, None] & (pb * block >= Lt[:, None])
        c["page_blocks"] = jnp.where(undo, snap["page_blocks"], pb)
        # pyramid: snapshot base + the kept window positions' exact fp32
        # contributions (same one-hot einsum as prefill_chunk's add)
        npages = cache["pyr_k"][0].shape[2]
        page = (pos // block) % npages
        keep_tok = need[:, None] & (pos < Lt[:, None])  # (B, W)
        ind_b = (page[:, :, None] == jnp.arange(npages)) & keep_tok[:, :, None]
        ind = ind_b.astype(jnp.float32)
        # a page recycled by a *kept* write starts its new block from zero —
        # the evicted block's snapshot sums are gone for good (same rule as
        # prefill_chunk's fresh mask, restricted to the accepted prefix)
        fresh = jnp.any(ind_b & ((pos % block) == 0)[:, :, None], axis=1)
        f4 = fresh[:, None, :, None]
        n4 = need[:, None, None, None]
        pyr_k, pyr_v = [], []
        for li in range(len(cache["pyr_k"])):
            base_k = jnp.where(f4, 0.0, snap["pyr_k"][li])
            base_v = jnp.where(f4, 0.0, snap["pyr_v"][li])
            if chunk_kv is not None:
                ck, cv = chunk_kv[0][li], chunk_kv[1][li]  # (B, Hkv, C, D)
                C = ck.shape[2]
                base_k = base_k + jnp.einsum("bcy,bhcd->bhyd", ind[:, :C], ck)
                base_v = base_v + jnp.einsum("bcy,bhcd->bhyd", ind[:, :C], cv)
            pyr_k.append(jnp.where(n4, base_k, cache["pyr_k"][li]))
            pyr_v.append(jnp.where(n4, base_v, cache["pyr_v"][li]))
        c["pyr_k"], c["pyr_v"] = pyr_k, pyr_v
        if hier_lids:
            # H-level hierarchy (§14): collapses performed during the round
            # folded evicted sums into shared tables and per-layer entries.
            # Restore the whole hierarchy to the snapshot for ``need``
            # slots, then replay exactly the collapses the *kept* writes
            # perform — evicted owners come from the snapshot page table at
            # the pages the kept prefix recycled (``fresh``), their sums
            # from the snapshot pyramid; ascending-block order matches
            # sequential decode, so the result is bit-identical to having
            # never speculated.
            from repro.core import hier

            n2, n3 = need[:, None], need[:, None, None]
            for lvl in hier_lids:
                c[f"hier_own{lvl}"] = jnp.where(
                    n2, snap[f"hier_own{lvl}"], cache[f"hier_own{lvl}"])
                c[f"hier_cnt{lvl}"] = jnp.where(
                    n2, snap[f"hier_cnt{lvl}"], cache[f"hier_cnt{lvl}"])
                for pre, m in (("hier_k", n4), ("hier_v", n4),
                               ("hier_ks", n3), ("hier_vs", n3)):
                    key = f"{pre}{lvl}"
                    c[key] = [jnp.where(m, s, a)
                              for a, s in zip(cache[key], snap[key])]
            c["tail_k"] = [jnp.where(n3, s, a)
                           for a, s in zip(cache["tail_k"], snap["tail_k"])]
            c["tail_v"] = [jnp.where(n3, s, a)
                           for a, s in zip(cache["tail_v"], snap["tail_v"])]
            c["tail_cnt"] = jnp.where(need, snap["tail_cnt"],
                                      cache["tail_cnt"])
            evicted = fresh & (snap["page_blocks"] >= 0)
            b1 = jnp.arange(need.shape[0])
            child_cnt = jnp.full(need.shape, block, jnp.int32)
            for blk_j, on_j in hier.eviction_schedule(
                    snap["page_blocks"], evicted, W // block + 1):
                tupd, plan = hier.cache_collapse_tables(
                    c, blk_j, child_cnt, on_j)
                c.update(tupd)
                pg = blk_j % npages
                for li in range(len(c["pyr_k"])):
                    hier.cache_store_layer(
                        c, li,
                        hier.cache_collapse_layer(
                            c, li, plan,
                            snap["pyr_k"][li][b1, :, pg],
                            snap["pyr_v"][li][b1, :, pg]))
        return c

    return jax.jit(gather), jax.jit(rewind)


class RingPagedKVCache(CacheBackend):
    """Engine-side decode state: KV pages + pyramid + page table + lengths.

    With ``mesh`` set, every tensor is placed by its ParamSpec logical axes
    (slots over the data axes, kv-heads over the model axis) so the decode
    and chunked-prefill steps run tensor-parallel (DESIGN.md §8/§9).
    """

    def __init__(self, cfg: ModelConfig, model, slots: int, max_len: int,
                 mesh=None):
        if cfg.attention.kind in ("mra2", "mra2_s"):
            if max_len % cfg.attention.block_size != 0:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of the MRA block "
                    f"size {cfg.attention.block_size} (pages are blocks)")
        self.cfg = cfg
        self.slots = slots
        self.capacity = max_len
        self.specs = model.cache_specs(cfg, slots, max_len)
        self.paged = "page_blocks" in self.specs
        self.supports_spec = self.paged
        self.block = cfg.attention.block_size if self.paged else None
        self.pages = max_len // cfg.attention.block_size if self.paged else None
        self.quantized = "k_scale" in self.specs
        # H-level hierarchy (DESIGN.md §14): the fine ring stays max_len
        # tokens (window_tokens), but evicted pages collapse up into the
        # hier_*/tail_* levels instead of being dropped, so the *logical*
        # context is unbounded — admission is not capped by the fine window
        # (capacity None, the StateCache precedent: arbitrarily long prompts
        # stream through chunked prefill). Chunks stay one block short of
        # the window (chunk_cap) so every token a chunk collapses is
        # strictly older than every query in that chunk.
        self.levels = cfg.attention.levels if self.paged else 2
        self.hier_lids = tuple(range(2, self.levels)) if self.paged else ()
        self.window_tokens = max_len
        if self.hier_lids:
            self.capacity = None
            self.chunk_cap = max_len - self.block
        self.tree = init_params(self.specs, jax.random.PRNGKey(0))
        if mesh is not None:
            self.tree = jax.tree.map(
                jax.device_put, self.tree, param_shardings(self.specs, mesh))
        self._reset = _make_reset(self.paged, self.hier_lids)

    def reset_slots(self, mask: np.ndarray):
        """Clear the slots selected by ``mask`` (B,) bool for re-admission."""
        self.tree = self._reset(self.tree, jnp.asarray(mask))

    # ---- speculative decoding: bounded ring snapshot / rewind -------------- #
    def spec_snapshot(self, window: int):
        """Capture the state a ``window``-token speculative round can destroy.

        O(window) per slot: the W physical K/V rows ahead of each slot's
        length are gathered; lengths, the page table, and the pyramid sums
        are retained by reference (jax arrays are immutable, and they are
        small — the big KV tensors are exactly what is NOT copied).
        """
        if not self.paged:
            raise NotImplementedError(
                "speculative rounds need the ring-paged MRA cache "
                "(pyramid pages are the draft model)")
        gather, _ = _make_spec_fns(window, self.block, self.quantized,
                                   self.hier_lids)
        t = self.tree
        snap = {
            "lengths": t["lengths"],
            "page_blocks": t["page_blocks"],
            "pyr_k": list(t["pyr_k"]),
            "pyr_v": list(t["pyr_v"]),
            "win": gather(t),
            "window": window,
        }
        for lvl in self.hier_lids:  # §14: by reference, like the pyramid
            for pre in ("hier_own", "hier_cnt"):
                snap[f"{pre}{lvl}"] = t[f"{pre}{lvl}"]
            for pre in ("hier_k", "hier_v", "hier_ks", "hier_vs"):
                snap[f"{pre}{lvl}"] = list(t[f"{pre}{lvl}"])
        if self.hier_lids:
            snap["tail_k"] = list(t["tail_k"])
            snap["tail_v"] = list(t["tail_v"])
            snap["tail_cnt"] = t["tail_cnt"]
        return snap

    def spec_rewind(self, snap, target_lengths, gate, chunk_kv=None):
        """Rewind ``gate`` slots to ``target_lengths`` (see _make_spec_fns)."""
        _, rewind = _make_spec_fns(snap["window"], self.block, self.quantized,
                                   self.hier_lids)
        self.tree = rewind(self.tree, {k: v for k, v in snap.items()
                                       if k != "window"},
                           target_lengths, gate, chunk_kv)

    @property
    def lengths(self) -> np.ndarray:
        return np.asarray(self.tree["lengths"])

    def occupancy(self) -> dict:
        """Occupancy gauges (DESIGN.md §13): live tokens/pages + evictions.

        ``tokens_live`` counts positions still attendable (the window from
        the oldest live page to the stream head), ``pages_live`` the
        non-evicted page-table entries, ``tokens_evicted`` the positions
        ring eviction has dropped. Dense (non-paged) storage never evicts.
        """
        lengths = self.lengths
        occ = {
            "slots_active": float((lengths > 0).sum()),
            "tokens_live": float(lengths.sum()),
            "pages_live": 0.0,
            "tokens_evicted": 0.0,
        }
        if self.paged:
            start = self.window_start()
            occ["tokens_live"] = float((lengths - start).sum())
            occ["pages_live"] = float(self.live_pages().sum())
            occ["tokens_evicted"] = float(start.sum())
        for lvl in self.hier_lids:
            # per-level gauges (§14): with a hierarchical cache, "evicted"
            # tokens are not dropped — they live on in collapsed entries
            # (level{l}_tokens) and ultimately the tail (tail_tokens).
            cnt = np.asarray(self.tree[f"hier_cnt{lvl}"])
            occ[f"level{lvl}_entries"] = float((cnt > 0).sum())
            occ[f"level{lvl}_tokens"] = float(cnt.sum())
        if self.hier_lids:
            occ["tail_tokens"] = float(np.asarray(self.tree["tail_cnt"]).sum())
        return occ

    def live_pages(self) -> Optional[np.ndarray]:
        """(B,) live (non-evicted) page count per slot; None when dense."""
        if not self.paged:
            return None
        return np.asarray((np.asarray(self.tree["page_blocks"]) >= 0).sum(-1))

    def window_start(self) -> np.ndarray:
        """(B,) oldest position still attendable (0 until eviction kicks in)."""
        if not self.paged:
            return np.zeros((self.slots,), np.int64)
        pb = np.asarray(self.tree["page_blocks"]).astype(np.int64)
        oldest = np.where(pb >= 0, pb, np.iinfo(np.int64).max).min(-1)
        oldest = np.where((pb >= 0).any(-1), oldest, 0)
        return oldest * self.block
