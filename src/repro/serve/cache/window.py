"""Hybrid sliding-window + recurrent cache backend (recurrentgemma family).

One tree, two per-layer kinds (DESIGN.md §12): the ``window`` layers hold a
W-entry K/V ring buffer keyed by absolute positions (``kv_pos``, -1 =
empty; W = min(cfg.local_window, max_len)), and the ``rglru`` layers hold
the RG-LRU hidden state plus the depthwise-conv tail. The layout is
``recurrentgemma.cache_specs`` — per-layer selection happens inside the
model's ``prefill_chunk``/``decode_step`` over its block pattern, so hybrid
local/global models fall out of the same engine mechanism with no special
cases in serve/engine.py.

Like the pure-recurrent backend the per-slot state is bounded (O(window)),
so there is no admission capacity; unlike the paged backend the ring keyed
by position needs ``chunk_cap = W``: a prefill chunk larger than the window
would scatter two tokens into the same ring entry in one dispatch (and the
chunk's own queries would lose keys they still attend). The engine clamps
its chunk size accordingly.
"""
from __future__ import annotations

import numpy as np

from .protocol import StateCache

__all__ = ["HybridWindowCache"]


class HybridWindowCache(StateCache):
    """Window-ring + RG-LRU state per slot; chunk size capped at the window."""

    def __init__(self, cfg, model, slots: int, max_len: int, mesh=None):
        super().__init__(cfg, model, slots, max_len, mesh=mesh)
        self.chunk_cap = min(cfg.local_window, max_len)

    def occupancy(self) -> dict:
        """Occupancy gauges (DESIGN.md §13): the window ring holds the last
        ``W = chunk_cap`` tokens per slot, so ring entries = min(L, W) and
        positions older than the window count as evicted (the RG-LRU state
        still carries them, but the local-attention layers cannot see
        them)."""
        lengths = self.lengths
        w = self.chunk_cap
        held = np.minimum(lengths, w)
        return {
            "slots_active": float((lengths > 0).sum()),
            "tokens_live": float(held.sum()),
            "pages_live": float(held.sum()),
            "tokens_evicted": float(np.maximum(lengths - w, 0).sum()),
        }
