"""Per-layer cache protocol: the engine-facing contract every backend meets.

The serving engine (serve/engine.py, DESIGN.md §9/§12) is architecture-
agnostic: it plans chunks and decode waves, and the *model* functions
(``prefill_chunk`` / ``decode_step`` resolved through models/registry.py)
own the cache tree's layout and numerics. What the engine needs from the
cache object is only lifecycle + introspection, and that is this protocol:

  tree           the device pytree handed to every jitted model call
  specs          the ParamSpec tree that declared it (mesh placement, dtypes)
  capacity       per-slot token budget for admission control, or ``None``
                 when the state is O(1)/O(window) per slot and the scheduler
                 must not reject on prompt length (recurrent backends)
  chunk_cap      optional ceiling on the engine's prefill chunk size (a
                 sliding-window ring can absorb at most W tokens per
                 dispatch without overwriting keys its own queries need)
  paged          ring-paged MRA semantics (page table + pyramid); drives the
                 scheduler's "generation may exceed capacity" rule
  supports_spec  whether spec_snapshot/spec_rewind exist — speculative
                 decoding drafts through the MRA pyramid and rewinds the
                 ring, so only the paged backend supports it
  reset_slots    bit-exact per-slot reset on (re)admission
  lengths        (slots,) host view of per-slot stream lengths

Which backend serves a model is decided per *layer* from the model's
``layer_cache_kinds(cfg)`` (see ``make_cache`` in __init__.py): every layer
kind maps to cache state the backend knows how to reset, and hybrid models
(recurrentgemma's local/rglru pattern) get one backend holding both kinds'
state in a single tree — per-layer selection, single lifecycle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import init_params, param_shardings


class CacheBackend:
    """Base class carrying the protocol defaults (see module docstring)."""

    paged = False
    supports_spec = False
    chunk_cap: int | None = None
    capacity: int | None = None
    kinds: tuple = ()

    def reset_slots(self, mask: np.ndarray) -> None:
        raise NotImplementedError

    @property
    def lengths(self) -> np.ndarray:
        return np.asarray(self.tree["lengths"])

    def occupancy(self) -> dict:
        """Uniform occupancy gauges (serve/telemetry.py, DESIGN.md §13).

        Keys every backend reports: ``slots_active`` (slots with a live
        stream), ``tokens_live`` (tokens the cache still conditions on),
        ``pages_live`` (occupied page/ring entries; 0 for pure state
        caches), ``tokens_evicted`` (tokens no longer attendable — ring
        evictions; 0 where state absorbs history instead of evicting it).
        """
        lengths = self.lengths
        return {
            "slots_active": float((lengths > 0).sum()),
            "tokens_live": float(lengths.sum()),
            "pages_live": 0.0,
            "tokens_evicted": 0.0,
        }

    # speculative decoding is a paged-backend feature (DESIGN.md §10/§12)
    def spec_snapshot(self, window: int):
        raise NotImplementedError(
            "speculative rounds need the ring-paged MRA cache "
            "(pyramid pages are the draft model)")

    def spec_rewind(self, snap, target_lengths, gate, chunk_kv=None):
        raise NotImplementedError(
            "speculative rounds need the ring-paged MRA cache "
            "(pyramid pages are the draft model)")


def fill_value(spec) -> float:
    """The constant a ``zeros``/``ones``/``fill`` ParamSpec initializes to.

    State backends reset a slot by rewriting its rows with this value, so
    reset ≡ fresh init bit-for-bit for every leaf (e.g. recurrentgemma's
    ``kv_pos`` ring positions fill with -1 = empty, not 0).
    """
    if spec.init == "zeros":
        return 0.0
    if spec.init == "ones":
        return 1.0
    if spec.init == "fill":
        return spec.scale
    raise ValueError(
        f"cache spec init {spec.init!r} has no reset constant; cache state "
        "must be declared zeros/ones/fill")


@functools.lru_cache(maxsize=None)
def make_state_reset(items: tuple):
    """Jitted bit-exact slot reset for a state-cache tree.

    ``items`` is a tuple of (key, fill) pairs. Layout convention shared by
    the recurrent/window backends: ``lengths`` is (slots,); every other leaf
    is (layers, slots, ...) with the slot axis second.
    """

    def reset(cache, mask):
        c = dict(cache)
        for key, fill in items:
            a = cache[key]
            if key == "lengths":
                m = mask
            else:
                m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
            c[key] = jnp.where(m, jnp.asarray(fill, a.dtype), a)
        return c

    return jax.jit(reset)


class StateCache(CacheBackend):
    """Shared lifecycle for fixed-size per-slot state trees (no paging).

    The tree is exactly ``model.cache_specs(cfg, slots, max_len)`` — the
    model owns the layout; this class owns init/placement/reset. Per-slot
    state is O(1) (recurrent) or O(window) (sliding-window ring), so there
    is no admission capacity: ``capacity`` stays None and the scheduler
    accepts any prompt/generation length.
    """

    capacity = None

    def __init__(self, cfg, model, slots: int, max_len: int, mesh=None):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.specs = model.cache_specs(cfg, slots, max_len)
        self.tree = init_params(self.specs, jax.random.PRNGKey(0))
        if mesh is not None:
            self.tree = jax.tree.map(
                jax.device_put, self.tree, param_shardings(self.specs, mesh))
        self._reset = make_state_reset(
            tuple(sorted((k, fill_value(s)) for k, s in self.specs.items())))

    def reset_slots(self, mask: np.ndarray) -> None:
        self.tree = self._reset(self.tree, jnp.asarray(mask))
