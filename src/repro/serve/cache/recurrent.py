"""Recurrent-state cache backend (RWKV-6 family, layer kind ``wkv``).

Per slot the state is O(1) in the stream length: one (H, dh, dh) wkv matrix
plus the token-shift carries (the previous token's normed activations for
the time-mix and channel-mix branches) per layer, and a length counter. The
tree layout is ``rwkv6.cache_specs``; chunked prefill advances it through
the generalized ``wkv_chunked`` (the chunk_rwkv6 dual-mode design:
chunk-parallel for prefill throughput, fused recurrence for decode latency)
and ``decode_step`` advances it one token at a time under an ``active``
mask, so ragged continuous batching preserves frozen slots bit-for-bit.

No admission capacity (``capacity = None``): prompts and generations of any
length fit in constant memory, which is the whole point of serving the
attention-free families through the same engine. Speculative decoding is
unsupported — there is no pyramid to draft from and no ring to rewind
(DESIGN.md §12).
"""
from __future__ import annotations

from .protocol import StateCache

__all__ = ["RecurrentStateCache"]


class RecurrentStateCache(StateCache):
    """Fixed-size wkv state per slot; lifecycle shared with StateCache.

    Occupancy telemetry (DESIGN.md §13) uses the protocol default: the wkv
    state *absorbs* history instead of paging it, so ``tokens_live`` is the
    total absorbed stream and ``pages_live`` / ``tokens_evicted`` stay 0 —
    nothing is ever dropped from a recurrent state.
    """
