"""serve/cache: per-layer cache protocol + backends (DESIGN.md §12).

``make_cache`` is the engine's single entry point: it reads the model's
per-layer cache kinds from the registry contract (``layer_cache_kinds``)
and picks the backend whose state covers them. The split keeps the engine
architecture-agnostic — serve/engine.py never mentions pyramids, wkv
states, or ring windows.
"""
from __future__ import annotations

from .paged import RingPagedKVCache, quantize_kv
from .protocol import CacheBackend, StateCache
from .recurrent import RecurrentStateCache
from .window import HybridWindowCache

__all__ = [
    "CacheBackend", "HybridWindowCache", "RecurrentStateCache",
    "RingPagedKVCache", "StateCache", "make_cache", "quantize_kv",
]

# layer kind -> backend family; every kind a model declares must land in
# exactly one backend (hybrids are legal within one backend's row)
_PAGED_KINDS = frozenset({"paged_kv", "kv"})
_RECURRENT_KINDS = frozenset({"wkv"})
_WINDOW_KINDS = frozenset({"window", "rglru"})


def make_cache(cfg, model, slots: int, max_len: int, mesh=None) -> CacheBackend:
    """Build the cache backend serving ``model``'s per-layer kinds."""
    kinds = tuple(model.layer_cache_kinds(cfg))
    ks = set(kinds)
    if ks <= _PAGED_KINDS:
        cache = RingPagedKVCache(cfg, model, slots, max_len, mesh=mesh)
    elif ks <= _RECURRENT_KINDS:
        cache = RecurrentStateCache(cfg, model, slots, max_len, mesh=mesh)
    elif ks <= _WINDOW_KINDS:
        cache = HybridWindowCache(cfg, model, slots, max_len, mesh=mesh)
    else:
        raise ValueError(
            f"no cache backend serves layer cache kinds {sorted(ks)} "
            f"(family {cfg.family!r})")
    cache.kinds = kinds
    return cache
