"""Token sampling for the serving engine: greedy / temperature / top-k / top-p.

Per-request parameters travel as ``SamplingParams`` on the ``Request``
(``sampling=None`` resolves to ``EngineConfig.default_sampling`` at submit,
greedy when that is unset too); the engine materializes them as per-slot
arrays so one jitted ``sample_batch`` serves every slot regardless of its
sampler settings (greedy is ``temperature == 0``). Sampling never touches
the cache, so the contract below holds identically for every cache backend
(paged KV, recurrent state, hybrid window — DESIGN.md §12).

Determinism contract (pinned by tests/test_engine.py): the PRNG key for a
request's ``i``-th sampled token is ``fold_in(PRNGKey(seed), i)`` — a pure
function of the request's seed and the token index, never of the slot it
landed in, the batch around it, or wall-clock state. Batched engine output is
therefore bit-identical to a single-request run with the same seed.

Speculative decoding (DESIGN.md §10) extends the same contract: every extra
random decision the draft/verify loop makes about the request's ``i``-th
token — drafting it, accepting it, resampling it on rejection — derives its
key as ``fold_in(fold_in(PRNGKey(seed), i), tag)`` with a fixed per-role tag,
so speculative serving stays a pure function of (seed, token index) and
batched ≡ solo stays bit-exact. The accept/resample math is standard
rejection sampling (Leviathan et al., 2023): accept draft ``d`` with
probability ``min(1, p(d)/q(d))``, resample rejections from
``norm(max(p - q, 0))`` — the emitted distribution is exactly ``p``
(pinned by a hypothesis property test in tests/test_mra_properties.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.mra import NEG_INF


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 (or negative) = greedy argmax; > 0 = softmax sampling.
    top_k: keep only the k highest logits (0 = disabled).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
      distribution with cumulative probability >= top_p (1.0 = disabled).
    seed: request-level PRNG seed (see determinism contract above).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()

# speculative-decoding key roles (see determinism contract above): the draft
# proposal, the accept test, and the rejection resample for token index i all
# need independent randomness that is still a pure function of (seed, i).
SPEC_DRAFT_TAG = 1
SPEC_ACCEPT_TAG = 2
SPEC_RESID_TAG = 3


def request_key(seed, step):
    """PRNG key for a request's ``step``-th sampled token."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def spec_key(seed, step, tag):
    """PRNG key for a speculative decision about the ``step``-th token."""
    return jax.random.fold_in(request_key(seed, step), tag)


def _masked_logits(logits, vocab):
    lf = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if vocab is not None and vocab < V:
        lf = jnp.where(jnp.arange(V) < vocab, lf, NEG_INF)
    return lf


def greedy_batch(logits, *, vocab=None):
    """Vocab-masked argmax — the sampler's temperature == 0 path, exactly.

    Split out so the engine's greedy fast path (no sort/softmax/cumsum per
    decode step) provably returns the same token ``sample_batch`` would.
    """
    return jnp.argmax(_masked_logits(logits, vocab), axis=-1).astype(jnp.int32)


def filtered_logits(logits, temperature, top_k, top_p, *, vocab=None):
    """Temperature-scaled, top-k/top-p-filtered logits: (B, V) -> (B, V).

    ``softmax(filtered_logits(...))`` is the exact distribution
    ``sample_batch`` draws from for a temperature > 0 slot. Split out so the
    speculative accept/resample primitive (``spec_verify_batch``) scores the
    *same* filtered target/draft distributions the oracle sampler uses —
    filtering and acceptance can never disagree about the support.
    """
    B, V = logits.shape
    lf = _masked_logits(logits, vocab)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: mask everything below the k-th largest logit (ties are kept —
    # deterministic, and the categorical renormalizes anyway); k <= 0 disables
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    kth = jnp.take_along_axis(sorted_desc, k[:, None] - 1, axis=-1)  # (B, 1)
    scaled = jnp.where(scaled >= kth, scaled, NEG_INF)
    # top-p over the top-k-filtered distribution: keep the smallest sorted
    # prefix whose cumulative probability reaches top_p (the argmax is always
    # kept, so top_p -> 0 degenerates to greedy). Top-k masking only replaces
    # the tail of the descending order with NEG_INF, so the filtered sorted
    # view is derivable from the first sort — no second O(V log V) sort on
    # the per-token serving hot path.
    sdesc = jnp.where(jnp.arange(V)[None, :] < k[:, None], sorted_desc, NEG_INF)
    p_sorted = jax.nn.softmax(sdesc, axis=-1)
    csum = jnp.cumsum(p_sorted, axis=-1)
    keep = (csum - p_sorted) < top_p[:, None]  # (B, V) in sorted order
    # top_p <= 0 keeps nothing above; clamp so the argmax always survives
    # (top_p -> 0 then degenerates to greedy instead of disabling the filter)
    n_keep = jnp.maximum(jnp.sum(keep, axis=-1).astype(jnp.int32), 1)
    cutoff = jnp.take_along_axis(sdesc, n_keep[:, None] - 1, axis=-1)
    return jnp.where(scaled >= cutoff, scaled, NEG_INF)


def sample_batch(logits, temperature, top_k, top_p, seed, step, *, vocab=None):
    """Sample one token per slot. All sampler params are per-slot arrays.

    Args:
      logits: (B, V) next-token logits (V may include vocab padding).
      temperature/top_p: (B,) float32; top_k/seed/step: (B,) int32.
      vocab: real vocab size — padded logit columns are masked out.

    Returns:
      (B,) int32 sampled token ids.
    """
    greedy_tok = greedy_batch(logits, vocab=vocab)
    scaled = filtered_logits(logits, temperature, top_k, top_p, vocab=vocab)
    keys = jax.vmap(request_key)(seed, step)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


def draft_batch(logits, temperature, top_k, top_p, seed, step, *, vocab=None):
    """Draft-propose one token per slot; also return its proposal distribution.

    Same filtering math as ``sample_batch`` but keyed with ``SPEC_DRAFT_TAG``
    (a draft proposal must not consume the oracle key of the token index it
    speculates about — on rejection the oracle key is still unspent).

    Returns:
      (q_probs (B, V) float32 filtered proposal distribution,
       tokens (B,) int32). For temperature <= 0 slots the token is the
      greedy argmax and ``q_probs`` is unused by the accept rule.
    """
    greedy_tok = greedy_batch(logits, vocab=vocab)
    scaled = filtered_logits(logits, temperature, top_k, top_p, vocab=vocab)
    q_probs = jax.nn.softmax(scaled, axis=-1)
    keys = jax.vmap(spec_key, (0, 0, None))(seed, step, SPEC_DRAFT_TAG)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return q_probs, jnp.where(temperature <= 0.0, greedy_tok, sampled)


def spec_residual(p, q):
    """Rejection-resample logits: ``log(max(p - q, 0))`` with an empty-support
    guard (p == q everywhere can only coincide with acceptance probability 1,
    so the fallback to ``log p`` is unreachable in exact arithmetic — it only
    catches float underflow)."""
    resid = jnp.maximum(p - q, 0.0)
    has = jnp.sum(resid, axis=-1, keepdims=True) > 0.0
    safe = jnp.where(has, resid, p)
    return jnp.log(jnp.maximum(safe, 1e-38))


def spec_verify_batch(logits, draft, q_probs, temperature, top_k, top_p, seed,
                      step0, active, *, vocab=None):
    """Verify K drafted tokens per slot against target logits.

    Standard speculative rejection sampling, vectorized over slots: draft i
    is accepted with probability ``min(1, p_i(d_i) / q_i(d_i))`` where p/q
    are the *filtered* target/draft distributions; the first rejection emits
    a resample from ``norm(max(p_i - q_i, 0))`` and discards the rest; full
    acceptance emits a bonus token from the (K+1)-th target distribution
    using the ordinary ``request_key`` — exactly the draw the non-speculative
    oracle would have made at that token index. Greedy slots
    (temperature <= 0) degenerate to "accept while the draft matches the
    target argmax, emit the target argmax at the first mismatch", which makes
    greedy speculative decode token-identical to the oracle by induction.

    Args:
      logits: (B, K+1, V) target logits; ``[:, i]`` conditions on the fed
        token plus drafts < i, i.e. it is the distribution of token index
        ``step0 + i``.
      draft: (B, K) int32 drafted tokens; q_probs (B, K, V) their filtered
        proposal distributions (from ``draft_batch``).
      temperature/top_p: (B,) float32; top_k/seed/step0: (B,) int32 with
        ``step0`` the token index of the first draft.
      active: (B,) bool — slots not in this speculative round emit nothing.

    Returns:
      (out (B, K+1) int32 — column j is the j-th token emitted this round,
       n_out (B,) int32 emitted count (accepted + 1; 0 where inactive),
       n_acc (B,) int32 accepted-draft count).
    """
    B, Kp1, V = logits.shape
    K = Kp1 - 1
    greedy = temperature <= 0.0
    alive = active
    n_acc = jnp.zeros((B,), jnp.int32)
    outs = []
    for i in range(K):
        li = logits[:, i]
        greedy_tok = greedy_batch(li, vocab=vocab)
        scaled = filtered_logits(li, temperature, top_k, top_p, vocab=vocab)
        p = jax.nn.softmax(scaled, axis=-1)
        d = draft[:, i]
        q = q_probs[:, i]
        pd = jnp.take_along_axis(p, d[:, None], axis=-1)[:, 0]
        qd = jnp.take_along_axis(q, d[:, None], axis=-1)[:, 0]
        u = jax.vmap(jax.random.uniform)(
            jax.vmap(spec_key, (0, 0, None))(seed, step0 + i, SPEC_ACCEPT_TAG))
        # u < pd/qd without the divide (qd >= 0; drafts have q(d) > 0)
        acc = jnp.where(greedy, d == greedy_tok, u * qd < pd) & alive
        keys_r = jax.vmap(spec_key, (0, 0, None))(seed, step0 + i,
                                                  SPEC_RESID_TAG)
        fix = jax.vmap(jax.random.categorical)(
            keys_r, spec_residual(p, q)).astype(jnp.int32)
        fix = jnp.where(greedy, greedy_tok, fix)
        outs.append(jnp.where(acc, d, fix))
        n_acc = n_acc + acc.astype(jnp.int32)
        alive = acc
    # bonus token after full acceptance: the ordinary oracle draw for index
    # step0 + K (only read by callers where every draft was accepted)
    bonus = sample_batch(logits[:, K], temperature, top_k, top_p, seed,
                         step0 + K, vocab=vocab)
    out = jnp.stack(outs + [bonus], axis=1)
    n_out = jnp.where(active, n_acc + 1, 0)
    return out, n_out, n_acc


def sample(logits, params: SamplingParams, step: int, *, vocab=None):
    """Single-sequence reference sampler: logits (V,) -> int32 token.

    Thin wrapper over ``sample_batch`` with B == 1 so conformance tests and
    batched serving share one code path by construction.
    """
    out = sample_batch(
        logits[None, :],
        jnp.asarray([params.temperature], jnp.float32),
        jnp.asarray([params.top_k], jnp.int32),
        jnp.asarray([params.top_p], jnp.float32),
        jnp.asarray([params.seed], jnp.int32),
        jnp.asarray([step], jnp.int32),
        vocab=vocab,
    )
    return out[0]
