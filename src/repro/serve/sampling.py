"""Token sampling for the serving engine: greedy / temperature / top-k / top-p.

Per-request parameters travel as ``SamplingParams`` on the ``Request``; the
engine materializes them as per-slot arrays so one jitted ``sample_batch``
serves every slot regardless of its sampler settings (greedy is
``temperature == 0``).

Determinism contract (pinned by tests/test_engine.py): the PRNG key for a
request's ``i``-th sampled token is ``fold_in(PRNGKey(seed), i)`` — a pure
function of the request's seed and the token index, never of the slot it
landed in, the batch around it, or wall-clock state. Batched engine output is
therefore bit-identical to a single-request run with the same seed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.mra import NEG_INF


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 (or negative) = greedy argmax; > 0 = softmax sampling.
    top_k: keep only the k highest logits (0 = disabled).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
      distribution with cumulative probability >= top_p (1.0 = disabled).
    seed: request-level PRNG seed (see determinism contract above).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()


def request_key(seed, step):
    """PRNG key for a request's ``step``-th sampled token."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def _masked_logits(logits, vocab):
    lf = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if vocab is not None and vocab < V:
        lf = jnp.where(jnp.arange(V) < vocab, lf, NEG_INF)
    return lf


def greedy_batch(logits, *, vocab=None):
    """Vocab-masked argmax — the sampler's temperature == 0 path, exactly.

    Split out so the engine's greedy fast path (no sort/softmax/cumsum per
    decode step) provably returns the same token ``sample_batch`` would.
    """
    return jnp.argmax(_masked_logits(logits, vocab), axis=-1).astype(jnp.int32)


def sample_batch(logits, temperature, top_k, top_p, seed, step, *, vocab=None):
    """Sample one token per slot. All sampler params are per-slot arrays.

    Args:
      logits: (B, V) next-token logits (V may include vocab padding).
      temperature/top_p: (B,) float32; top_k/seed/step: (B,) int32.
      vocab: real vocab size — padded logit columns are masked out.

    Returns:
      (B,) int32 sampled token ids.
    """
    B, V = logits.shape
    lf = _masked_logits(logits, vocab)
    greedy_tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: mask everything below the k-th largest logit (ties are kept —
    # deterministic, and the categorical renormalizes anyway); k <= 0 disables
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    kth = jnp.take_along_axis(sorted_desc, k[:, None] - 1, axis=-1)  # (B, 1)
    scaled = jnp.where(scaled >= kth, scaled, NEG_INF)
    # top-p over the top-k-filtered distribution: keep the smallest sorted
    # prefix whose cumulative probability reaches top_p (the argmax is always
    # kept, so top_p -> 0 degenerates to greedy). Top-k masking only replaces
    # the tail of the descending order with NEG_INF, so the filtered sorted
    # view is derivable from the first sort — no second O(V log V) sort on
    # the per-token serving hot path.
    sdesc = jnp.where(jnp.arange(V)[None, :] < k[:, None], sorted_desc, NEG_INF)
    p_sorted = jax.nn.softmax(sdesc, axis=-1)
    csum = jnp.cumsum(p_sorted, axis=-1)
    keep = (csum - p_sorted) < top_p[:, None]  # (B, V) in sorted order
    # top_p <= 0 keeps nothing above; clamp so the argmax always survives
    # (top_p -> 0 then degenerates to greedy instead of disabling the filter)
    n_keep = jnp.maximum(jnp.sum(keep, axis=-1).astype(jnp.int32), 1)
    cutoff = jnp.take_along_axis(sdesc, n_keep[:, None] - 1, axis=-1)
    scaled = jnp.where(scaled >= cutoff, scaled, NEG_INF)

    keys = jax.vmap(request_key)(seed, step)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


def sample(logits, params: SamplingParams, step: int, *, vocab=None):
    """Single-sequence reference sampler: logits (V,) -> int32 token.

    Thin wrapper over ``sample_batch`` with B == 1 so conformance tests and
    batched serving share one code path by construction.
    """
    out = sample_batch(
        logits[None, :],
        jnp.asarray([params.temperature], jnp.float32),
        jnp.asarray([params.top_k], jnp.int32),
        jnp.asarray([params.top_p], jnp.float32),
        jnp.asarray([params.seed], jnp.int32),
        jnp.asarray([step], jnp.int32),
        vocab=vocab,
    )
    return out[0]
