"""Abstract input builders for dry-runs: ShapeDtypeStruct stand-ins with
shardings attached, zero device allocation."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeCfg
from repro.distributed.sharding import ShardingRules, logical_to_pspec
from repro.models import abstract_params, get_model


def _sds(shape, dtype, mesh, axes, rules):
    spec = logical_to_pspec(shape, axes, mesh, rules)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeCfg, mesh, rules: Optional[ShardingRules] = None):
    """Abstract train/prefill batch for this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    bax = ("batch",)
    if cfg.family == "hubert":
        return {
            "frames": _sds((B, S, cfg.frontend_dim), jnp.float32, mesh,
                           bax + (None, None), rules),
            "mask_positions": _sds((B, S), jnp.bool_, mesh, bax + (None,), rules),
            "targets": _sds((B, S), jnp.int32, mesh, bax + (None,), rules),
        }
    if cfg.family == "internvl":
        P_ = cfg.num_patches
        return {
            "tokens": _sds((B, S - P_), jnp.int32, mesh, bax + (None,), rules),
            "patches": _sds((B, P_, cfg.frontend_dim), jnp.float32, mesh,
                            bax + (None, None), rules),
            "targets": _sds((B, S - P_), jnp.int32, mesh, bax + (None,), rules),
        }
    return {
        "tokens": _sds((B, S), jnp.int32, mesh, bax + (None,), rules),
        "targets": _sds((B, S), jnp.int32, mesh, bax + (None,), rules),
    }


def cache_abstract(cfg: ModelConfig, shape: ShapeCfg, mesh,
                   rules: Optional[ShardingRules] = None):
    model = get_model(cfg)
    specs = model.cache_specs(cfg, shape.global_batch, shape.seq_len)
    return abstract_params(specs, mesh, rules)


def decode_tokens_abstract(cfg: ModelConfig, shape: ShapeCfg, mesh,
                           rules: Optional[ShardingRules] = None):
    return _sds((shape.global_batch,), jnp.int32, mesh, ("batch",), rules)


def params_abstract(cfg: ModelConfig, mesh, rules: Optional[ShardingRules] = None):
    model = get_model(cfg)
    return abstract_params(model.param_specs(cfg), mesh, rules)
