"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh) cell, all per-device (the dry-run records
per-device HLO stats from the SPMD-partitioned module):

    compute term    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory term     = HLO_bytes / HBM_bw                (819 GB/s)
    collective term = collective_bytes / link_bw        (~50 GB/s/link ICI)

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference; N active for MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs. For scanned train cells the
dry-run records depth-extrapolated HLO costs (cost_extrapolated) because XLA
cost analysis does not descend into while bodies.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline            # print table
    PYTHONPATH=src python -m repro.launch.roofline --markdown # EXPERIMENTS block
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def load_cells(results_dir=RESULTS_DIR):
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def analyze(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    cost = cell.get("cost_extrapolated") or cell.get("cost") or {}
    coll = cell.get("collectives_extrapolated") or cell.get("collectives") or {}
    flops = cost.get("flops_per_device", 0.0)
    bts = cost.get("bytes_accessed_per_device", 0.0)
    coll_b = sum(v for k, v in coll.items() if k != "count")
    t_c = flops / PEAK_FLOPS
    t_m = bts / HBM_BW
    t_l = coll_b / LINK_BW
    # Analytic memory FLOOR: every input byte read + output byte written once
    # (params/opt-state/KV-cache traffic). The XLA "bytes accessed" figure is
    # an UNFUSED upper bound from the CPU backend — fusion on TPU collapses
    # most intermediate traffic, so the truth lies between floor and bound.
    mem = cell.get("memory", {})
    floor_b = mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
    t_m_floor = floor_b / HBM_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))[1]
    dom_floor = max((t_c, "compute"), (t_m_floor, "memory"), (t_l, "collective"))[1]
    chips = cell.get("chips", 256)
    useful = cell.get("model_flops_total", 0.0) / chips
    out = {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_floor_s": t_m_floor,
        "collective_s": t_l,
        "dominant": dom,
        "dominant_floor": dom_floor,
        "model_flops_per_device": useful,
        "hlo_flops_per_device": flops,
        "useful_ratio": (useful / flops) if flops else 0.0,
        "mem_gib_per_device": cell.get("memory", {}).get("total_per_device_bytes", 0) / 2**30,
        "fits_16g": cell.get("memory", {}).get("total_per_device_bytes", 0) < 16 * 2**30,
        # roofline fraction: useful compute time / total modeled time (no overlap)
        "roofline_fraction": (useful / PEAK_FLOPS) / max(t_c + t_m + t_l, 1e-30),
        # with perfect compute/comm overlap the bound is the max term instead
        "roofline_fraction_overlap": (useful / PEAK_FLOPS) / max(t_c, t_m, t_l, 1e-30),
        # floor accounting: memory term from the analytic floor (TPU-fused view)
        "roofline_fraction_floor": (useful / PEAK_FLOPS)
        / max(t_c, t_m_floor, t_l, 1e-30),
    }
    return out


def suggestion(row: dict) -> str:
    if row["dominant"] == "compute":
        if row["useful_ratio"] < 0.5:
            return "cut non-useful FLOPs (replicated attention / remat recompute)"
        return "raise MRA block budget utilization / MXU-align tiles"
    if row["dominant"] == "memory":
        return "bf16 intermediates + fuse MRA gathers (Pallas kernel on TPU)"
    return "reshard to cut collectives (a2a MoE dispatch, overlap with compute)"


def table(cells, markdown=False):
    rows = [r for r in (analyze(c) for c in cells) if r]
    skips = [c for c in cells if c.get("status") == "skipped"]
    errs = [c for c in cells if c.get("status") == "error"]
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "mem_floor_s",
           "collective_s", "dom", "dom_floor", "useful_ratio", "mem_GiB",
           "rf_sum", "rf_overlap", "rf_floor"]
    lines = []
    sep = " | " if markdown else "  "
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        vals = [r["arch"], r["shape"], r["mesh"],
                f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                f"{r['memory_floor_s']:.3e}",
                f"{r['collective_s']:.3e}", r["dominant"], r["dominant_floor"],
                f"{r['useful_ratio']:.2f}", f"{r['mem_gib_per_device']:.1f}",
                f"{r['roofline_fraction']:.3f}",
                f"{r['roofline_fraction_overlap']:.3f}",
                f"{r['roofline_fraction_floor']:.3f}"]
        lines.append(("| " if markdown else "") + sep.join(vals) + (" |" if markdown else ""))
    for c in skips:
        lines.append(f"{'| ' if markdown else ''}{c['arch']}{sep}{c['shape']}{sep}{c['mesh']}"
                     f"{sep}SKIPPED: {c['reason']}{' |' if markdown else ''}")
    for c in errs:
        lines.append(f"{'| ' if markdown else ''}{c['arch']}{sep}{c['shape']}{sep}{c['mesh']}"
                     f"{sep}ERROR: {c['error'][:90]}{' |' if markdown else ''}")
    return "\n".join(lines), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    cells = load_cells()
    txt, rows = table(cells, markdown=args.markdown)
    print(txt)
    if rows:
        print("\nPer-dominant-term counts:",
              {d: sum(1 for r in rows if r["dominant"] == d)
               for d in ("compute", "memory", "collective")})
        worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:3]
        print("Worst roofline fractions:",
              [(r["arch"], r["shape"], r["mesh"], round(r["roofline_fraction"], 4))
               for r in worst])
        collb = sorted(rows, key=lambda r: -r["collective_s"])[:3]
        print("Most collective-bound:",
              [(r["arch"], r["shape"], r["mesh"], f"{r['collective_s']:.2e}s")
               for r in collb])
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
