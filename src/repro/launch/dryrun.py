import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell against the production meshes and record memory/cost/collective stats.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod-only

Results are cached as JSON under results/dryrun/ (one file per cell); the
roofline tool (launch/roofline.py) and EXPERIMENTS.md read from there.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_skips
from repro.distributed import mesh_utils
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_specs,
    cache_abstract,
    decode_tokens_abstract,
    params_abstract,
)
from repro.models import get_model
from repro.optim import AdamW, cosine_schedule
from repro.train import TrainConfig, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

_COLL_RE = re.compile(
    r"%?([\w.-]+)\s*=\s*(\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE,
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _parse_type_bytes(type_str: str) -> int:
    """'f32[16,256]' or tuple '(f32[2], f32[3])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective in partitioned HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        kind = m.group(3).lower()
        # operand bytes: parse types inside the call parens from operand list —
        # approximate with the *result* type (equals operand total for
        # all-reduce/permute; gather output >= input so this upper-bounds).
        out[kind] += _parse_type_bytes(m.group(2))
        out["count"] += 1
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with MoE active-param correction."""
    from repro.models import count_params, get_model

    model = get_model(cfg)
    specs = model.param_specs(cfg)
    total = count_params(specs)
    active = total
    if cfg.moe is not None:
        from repro.models.moe import moe_specs
        from repro.models import count_params as cp

        expert_per_layer = cp(moe_specs(cfg)) - cfg.d_model * cfg.moe.num_experts
        n_moe = cfg.num_layers
        expert_total = expert_per_layer * n_moe
        active = total - expert_total + expert_total * cfg.moe.top_k / cfg.moe.num_experts
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # one token per sequence per serve_step
    return 2.0 * active * tokens


# §Perf optimized-variant overrides (EXPERIMENTS.md §Perf; the paper-faithful
# baseline never applies these). Applied with --opt / opt=True.
OPT_OVERRIDES = {
    # TP-shard attention via head padding for archs whose heads don't divide 16
    "qwen2-7b": {"pad_attn_heads_to": 16},
    "llama3.2-3b": {"pad_attn_heads_to": 16},
    "internvl2-1b": {"pad_attn_heads_to": 16},
    "granite-moe-3b-a800m": {"pad_attn_heads_to": 16},
}

# int8 KV cache for decode shapes (§Perf Y3) — every MRA decoder arch
OPT_ATTN_OVERRIDES_DECODE = {"kv_quant": True}

# FSDP-style weight sharding over the data axes for params that dwarf HBM
# (kimi-k2: 1T params; GSPMD inserts the per-layer weight all-gathers)
OPT_RULES = {
    "kimi-k2-1t-a32b": {"d_model": (("data",),)},
}
OPT_CONFIG = {
    "kimi-k2-1t-a32b": {"moe_dispatch": "a2a", "param_dtype": "bfloat16"},
}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, do_compile: bool = True,
               attention_override: dict | None = None, opt: bool = False,
               config_override: dict | None = None):
    from repro.distributed.sharding import ShardingRules

    cfg = get_config(arch)
    rules = None
    if opt and arch in OPT_OVERRIDES:
        cfg = cfg.replace(**OPT_OVERRIDES[arch])
    if opt and arch in OPT_CONFIG:
        cfg = cfg.replace(**OPT_CONFIG[arch])
    if opt and arch in OPT_RULES:
        rules = ShardingRules().override(**OPT_RULES[arch])
    if opt and SHAPES[shape_name].kind == "decode" and cfg.attention.kind in ("mra2", "mra2_s"):
        attention_override = {**OPT_ATTN_OVERRIDES_DECODE, **(attention_override or {})}
    if config_override:
        cfg = cfg.replace(**config_override)
    if attention_override:
        import dataclasses

        cfg = cfg.replace(attention=dataclasses.replace(cfg.attention, **attention_override))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = get_model(cfg)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
        "attention": cfg.attention.kind,
    }

    t0 = time.time()
    with mesh_utils.use_mesh(mesh):
        params = params_abstract(cfg, mesh, rules)
        if shape.kind == "train":
            optimizer = AdamW()
            lr_fn = cosine_schedule(1e-4, 10, 1000)
            tc = TrainConfig(microbatches=1)
            step_fn = make_train_step(cfg, tc, optimizer, lr_fn)
            opt_state = optimizer.abstract_state(params, mesh, rules)
            batch = batch_specs(cfg, shape, mesh, rules)
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            batch = batch_specs(cfg, shape, mesh, rules)
            cache = cache_abstract(cfg, shape, mesh, rules)

            def prefill_fn(p, b, c):
                return model.prefill(p, cfg, b, c)

            lowered = jax.jit(prefill_fn, donate_argnums=(2,)).lower(params, batch, cache)
        else:  # decode
            cache = cache_abstract(cfg, shape, mesh, rules)
            tokens = decode_tokens_abstract(cfg, shape, mesh, rules)

            def serve_step(p, c, t):
                return model.decode_step(p, cfg, c, t)

            lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(params, cache, tokens)
        result["lower_s"] = round(time.time() - t0, 2)

        if do_compile:
            t1 = time.time()
            compiled = lowered.compile()
            result["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            result["memory"] = {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "total_per_device_bytes": (
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                ),
            }
            ca = compiled.cost_analysis()
            result["cost"] = {
                "flops_per_device": float(ca.get("flops", 0.0)),
                "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            }
            result["collectives"] = collective_bytes(compiled.as_text())

        # XLA cost analysis does not descend into `while` bodies (verified
        # empirically, DESIGN.md §6) — for scanned train cells, recover true
        # per-step costs by lowering unrolled depth-1 and depth-2 variants and
        # extrapolating linearly in depth.
        if do_compile and shape.kind == "train" and cfg.scan_layers:
            period = max(len(cfg.block_pattern), 1)
            sub = {}
            for mult in (1, 2):
                cfg_small = cfg.replace(num_layers=period * mult, scan_layers=False)
                step_small = make_train_step(
                    cfg_small, TrainConfig(microbatches=1), AdamW(),
                    cosine_schedule(1e-4, 10, 1000),
                )
                p_s = params_abstract(cfg_small, mesh, rules)
                o_s = AdamW().abstract_state(p_s, mesh, rules)
                b_s = batch_specs(cfg_small, shape, mesh, rules)
                comp = jax.jit(step_small, donate_argnums=(0, 1)).lower(p_s, o_s, b_s).compile()
                ca_s = comp.cost_analysis()
                sub[mult] = {
                    "flops": float(ca_s.get("flops", 0.0)),
                    "bytes": float(ca_s.get("bytes accessed", 0.0)),
                    "coll": collective_bytes(comp.as_text()),
                }
            n_units = cfg.num_layers / period
            def _ext(a, b):
                return a + (n_units - 1) * (b - a)
            coll1, coll2 = sub[1]["coll"], sub[2]["coll"]
            result["cost_extrapolated"] = {
                "flops_per_device": _ext(sub[1]["flops"], sub[2]["flops"]),
                "bytes_accessed_per_device": _ext(sub[1]["bytes"], sub[2]["bytes"]),
                "method": f"unrolled depth {period}/{2*period} linear extrapolation",
            }
            result["collectives_extrapolated"] = {
                k: _ext(coll1[k], coll2[k]) for k in coll1
            }
        result["model_flops_total"] = model_flops(cfg, shape)
    return result


def run_cell(arch, shape_name, multi_pod, *, force=False):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    fname = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(fname) and not force:
        cached = json.load(open(fname))
        if cached.get("status") in ("ok", "skipped"):
            print(f"[cached] {arch} x {shape_name} x {mesh_tag}")
            return cached
    skip = shape_skips(arch, shape_name)
    if skip:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": skip}
    else:
        try:
            res = lower_cell(arch, shape_name, multi_pod=multi_pod)
            res["status"] = "ok"
            print(f"[ok] {arch} x {shape_name} x {mesh_tag}: "
                  f"lower {res['lower_s']}s compile {res.get('compile_s', '-')}s "
                  f"mem {res.get('memory', {}).get('total_per_device_bytes', 0) / 2**30:.2f} GiB/dev")
        except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
            res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"[FAIL] {arch} x {shape_name} x {mesh_tag}: {type(e).__name__}: {e}")
    with open(fname, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only or args.multi_pod:
        meshes = [True]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                res = run_cell(arch, shape, mp, force=args.force)
                if res.get("status") == "error":
                    n_fail += 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
