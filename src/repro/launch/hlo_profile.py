"""Dry-run profiler: attribute HLO dot/collective costs to named ops.

No real-TPU timings exist in this container — per the assignment, the profile
is the lowered/compiled HLO itself. This tool parses the (partitioned,
optimized) HLO text and reports FLOPs per dot (with metadata op names), the
biggest tensors, and collective traffic, so §Perf hypotheses are grounded in
where the compiled module actually spends work.
"""
from __future__ import annotations

import collections
import re

_TYPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DOT = re.compile(
    r"%?[\w.-]+ = ([a-z0-9]+\[[0-9,]*\])[^=]*? dot\(([^)]*)\)"
    r".*?lhs_contracting_dims=\{([0-9,]*)\}", re.DOTALL)
_META = re.compile(r'op_name="([^"]+)"')


def _dims(type_str):
    m = _TYPE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def dot_flops_by_op(hlo_text: str, top: int = 15):
    """Total flops per metadata op_name for every dot in the module."""
    # build op -> type map for operand lookup
    types = {}
    for m in re.finditer(r"%?([\w.-]+) = ([a-z0-9]+\[[0-9,]*\])", hlo_text):
        types[m.group(1)] = m.group(2)

    out = collections.Counter()
    total = 0
    for line in hlo_text.splitlines():
        if " dot(" not in line:
            continue
        m = re.search(
            r"%?[\w.-]+ = ([a-z0-9]+\[[0-9,]*\]).* dot\((.*?)\)", line)
        if not m:
            continue
        out_dims = _dims(m.group(1))
        operands = [o.strip().lstrip("%") for o in m.group(2).split(",")]
        cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        lhs_t = types.get(operands[0].split(" ")[0])
        if out_dims is None or cd is None or lhs_t is None:
            continue
        lhs_dims = _dims(lhs_t)
        contract = 1
        for d in cd.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
        flops = 2 * contract
        for d in out_dims:
            flops *= d
        meta = _META.search(line)
        name = meta.group(1) if meta else "<no-meta>"
        # strip jit prefixes, keep the semantic tail
        name = "/".join(name.split("/")[-3:])
        out[name] += flops
        total += flops
    rows = out.most_common(top)
    return total, rows


def report(compiled, top: int = 15):
    txt = compiled.as_text()
    total, rows = dot_flops_by_op(txt, top)
    print(f"total dot flops/device: {total:.3e}")
    for name, fl in rows:
        print(f"  {fl:.3e}  ({fl/max(total,1):5.1%})  {name}")
    return total, rows
