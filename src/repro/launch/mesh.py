"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state (DESIGN.md / dry-run contract)."""
from __future__ import annotations

from typing import Optional

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 accepts axis_types; 0.4.x does not. All axes here are Auto
    # (the default on every version), so omitting the kwarg is equivalent.
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (TypeError, AttributeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (possibly host) devices are available."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def parse_mesh(spec: Optional[str]):
    """Parse a ``--mesh`` flag into a (data, model) mesh, or None.

    Accepted forms: ``"1"``/``""``/None (single device, no mesh), ``"4"``
    (data=4, model=1), ``"2x4"`` (data=2, model=4). The total must not
    exceed ``jax.device_count()`` — use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake host
    devices for CPU validation.
    """
    if not spec or spec == "1":
        return None
    parts = spec.lower().split("x")
    if len(parts) == 1:
        n_data, n_model = int(parts[0]), 1
    elif len(parts) == 2:
        n_data, n_model = int(parts[0]), int(parts[1])
    else:
        raise ValueError(f"bad mesh spec {spec!r}; expected 'D' or 'DxM'")
    if n_data * n_model == 1:
        return None
    avail = jax.device_count()
    if n_data * n_model > avail:
        raise ValueError(
            f"mesh {spec!r} needs {n_data * n_model} devices, have {avail} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    return make_local_mesh(n_data, n_model)
