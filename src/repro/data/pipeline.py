"""Deterministic synthetic data pipeline (offline container; DESIGN.md §7).

Streams are seeded and reproducible across restarts (step -> same batch), so
checkpoint/restart resumes bit-identically without data-state checkpointing
beyond the step counter. Structure matters for the paper's technique: token
streams mix Zipfian unigrams with copy/Markov structure so attention has the
locality MRA exploits; audio frames are temporally-correlated random walks.

Host sharding: each process materializes only its slice (process_index /
process_count), standard multi-host JAX data loading. A double-buffered
prefetch thread overlaps host data generation with device steps.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg


def _rng_for_step(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step, shard]))


def _lm_tokens(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Zipfian unigrams + local copy structure (gives MRA-friendly locality)."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq), p=probs).astype(np.int32)
    # copy spans: each sequence repeats an earlier span at a random offset
    n_spans = max(1, seq // 256)
    for b in range(batch):
        for _ in range(n_spans):
            ln = min(int(rng.integers(8, 33)), max(seq // 3, 1))
            if seq < 3 * ln:
                continue
            src = int(rng.integers(0, seq - 2 * ln + 1))
            dst = int(rng.integers(src + ln, seq - ln + 1))
            toks[b, dst : dst + ln] = toks[b, src : src + ln]
    return toks


def _audio_frames(rng, batch, seq, dim):
    steps = rng.standard_normal((batch, seq, dim)).astype(np.float32) * 0.3
    frames = np.cumsum(steps, axis=1)
    frames /= np.maximum(np.abs(frames).max(axis=(1, 2), keepdims=True), 1.0)
    return frames


def make_batch(cfg: ModelConfig, shape: ShapeCfg, *, step: int = 0, seed: int = 0,
               shard: int = 0, num_shards: int = 1, batch_override: Optional[int] = None):
    """One host-local training/prefill batch as numpy arrays."""
    B = batch_override if batch_override is not None else shape.global_batch // num_shards
    S = shape.seq_len
    rng = _rng_for_step(seed, step, shard)
    if cfg.family == "hubert":
        frames = _audio_frames(rng, B, S, cfg.frontend_dim)
        mask = rng.random((B, S)) < 0.08
        proj = _rng_for_step(seed, 0, 0).standard_normal((cfg.frontend_dim, cfg.vocab))
        targets = (frames @ proj.astype(np.float32)).argmax(-1).astype(np.int32)
        return {"frames": frames, "mask_positions": mask, "targets": targets}
    if cfg.family == "internvl":
        P = cfg.num_patches
        S_text = S - P
        toks = _lm_tokens(rng, B, S_text + 1, cfg.vocab)
        patches = _audio_frames(rng, B, P, cfg.frontend_dim)
        return {
            "tokens": toks[:, :-1],
            "patches": patches,
            "targets": toks[:, 1:].astype(np.int32),
        }
    toks = _lm_tokens(rng, B, S + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:].astype(np.int32)}


class DataLoader:
    """Double-buffered prefetching loader over ``make_batch``."""

    def __init__(self, cfg: ModelConfig, shape: ShapeCfg, *, seed: int = 0,
                 start_step: int = 0, shard: int = 0, num_shards: int = 1,
                 batch_override: Optional[int] = None, prefetch: int = 2):
        self.cfg, self.shape = cfg, shape
        self.seed, self.shard, self.num_shards = seed, shard, num_shards
        self.batch_override = batch_override
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(
                self.cfg, self.shape, step=step, seed=self.seed,
                shard=self.shard, num_shards=self.num_shards,
                batch_override=self.batch_override,
            )
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
