from .pipeline import DataLoader, make_batch
