"""Pure-jnp oracle for the block-sparse attention kernel.

Computes exactly the kernel's I/O contract (unnormalized numerator + row
sums over the selected blocks) with plain gathers/einsums. Used by tests to
validate the Pallas kernel in interpret mode and by the custom_vjp backward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _gather_blocks(x: jax.Array, idx: jax.Array, b: int) -> jax.Array:
    """x (R, n, d), idx (R, m) block ids -> (R, m, b, d)."""
    R, n, d = x.shape
    xb = x.reshape(R, n // b, b, d)
    return jnp.take_along_axis(xb, idx[..., None, None], axis=1)


def block_sparse_attention_ref(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    x_idx: jax.Array,  # (BHG, m)
    y_idx: jax.Array,  # (BHG, m)
    flags: jax.Array,  # (BHG, m) bit0 valid, bit1 causal-diag
    c: jax.Array,  # (BHG, nb)
    *,
    scale: float,
    block_size: int,
):
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    G = BHG // BHKV
    b = block_size
    nb = n // b
    m = x_idx.shape[1]

    kx = jnp.broadcast_to(k[:, None], (BHKV, G, n, d)).reshape(BHG, n, d)
    vx = jnp.broadcast_to(v[:, None], (BHKV, G, n, d)).reshape(BHG, n, d)

    q_blk = _gather_blocks(q.astype(jnp.float32), x_idx, b)  # (BHG, m, b, d)
    k_blk = _gather_blocks(kx.astype(jnp.float32), y_idx, b)
    v_blk = _gather_blocks(vx.astype(jnp.float32), y_idx, b)
    c_sel = jnp.take_along_axis(c, x_idx, axis=1)  # (BHG, m)

    s = jnp.einsum("rmid,rmjd->rmij", q_blk, k_blk) * scale - c_sel[..., None, None]
    valid = (flags & 1) == 1
    diag = (flags & 2) == 2
    tri = jnp.arange(b)[:, None] >= jnp.arange(b)[None, :]
    mask = jnp.where(diag[..., None, None], tri[None, None], True)
    mask = jnp.logical_and(mask, valid[..., None, None])
    a = jnp.where(mask, jnp.exp(jnp.minimum(s, 80.0)), 0.0)

    o_blk = jnp.einsum("rmij,rmjd->rmid", a, v_blk)
    r_blk = jnp.sum(a, axis=-1)

    seg = jax.vmap(lambda z, i, u: z.at[i].add(u))
    out = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), x_idx, o_blk).reshape(BHG, n, d)
    rowsum = seg(jnp.zeros((BHG, nb, b), jnp.float32), x_idx, r_blk).reshape(BHG, n)
    return out, rowsum
