"""Pure-jnp oracle for the block-sparse attention kernels (fwd + bwd).

Computes exactly the kernels' I/O contract — unnormalized numerator, row
sums and the per-token stabilizer ``mt``, plus their VJP — with plain
gathers/einsums. Used by tests to validate the Pallas kernels in interpret
mode and by the custom_vjp backward as the jnp fallback (DESIGN.md §3).

Stabilizer semantics (shared with the Pallas kernels): mt[token] is the max
of the floor ``c[query block]`` and every masked score the token sees across
its selected blocks; weights are exp(s − mt) ≤ 1 so nothing overflows, fwd
or bwd. mt is gradient-transparent (stop_gradient — it cancels in the
caller's normalization), hence dc ≡ 0 by contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mra import NEG_INF  # shared finite "minus infinity" sentinel


def _gather_blocks(x: jax.Array, idx: jax.Array, b: int) -> jax.Array:
    """x (R, n, d), idx (R, m) block ids -> (R, m, b, d)."""
    R, n, d = x.shape
    xb = x.reshape(R, n // b, b, d)
    return jnp.take_along_axis(xb, idx[..., None, None], axis=1)


def _expand_kv(x: jax.Array, G: int) -> jax.Array:
    """(BHKV, n, d) -> (BHG, n, d) by repeating each KV head G times."""
    BHKV, n, d = x.shape
    return jnp.broadcast_to(x[:, None], (BHKV, G, n, d)).reshape(BHKV * G, n, d)


def _block_mask(flags: jax.Array, km_blk: Optional[jax.Array], b: int) -> jax.Array:
    """(BHG, m) flags (+ optional (BHG, m, b) key-block mask) -> (BHG, m, b, b).

    flags bit0: pair valid; bit1: apply the causal triangular mask (diagonal
    blocks). ``km_blk`` marks valid *keys* inside each selected key block.
    """
    valid = (flags & 1) == 1
    diag = (flags & 2) == 2
    tri = jnp.arange(b)[:, None] >= jnp.arange(b)[None, :]
    mask = jnp.where(diag[..., None, None], tri[None, None], True)
    mask = jnp.logical_and(mask, valid[..., None, None])
    if km_blk is not None:
        mask = jnp.logical_and(mask, (km_blk > 0)[..., None, :])
    return mask


def _recompute(q, k, c, x_idx, y_idx, flags, key_mask, *, scale, block_size):
    """Shared fwd/bwd recompute: masked scores, per-token stabilizer, weights.

    Returns (a, q_blk, k_blk, mt) with a (BHG, m, b, b) = mask·exp(s − mt),
    mt (BHG, nb, b) = max(c floor, masked score row max), stop-gradient.
    """
    b = block_size
    BHG, n, _ = q.shape
    nb = n // b
    G = BHG // k.shape[0]
    kx = _expand_kv(k, G)
    q_blk = _gather_blocks(q.astype(jnp.float32), x_idx, b)
    k_blk = _gather_blocks(kx.astype(jnp.float32), y_idx, b)
    s = jnp.einsum("rmid,rmjd->rmij", q_blk, k_blk) * scale
    km_blk = None
    if key_mask is not None:
        kmx = _expand_kv(key_mask[..., None].astype(jnp.float32), G)[..., 0]
        km_blk = jnp.take_along_axis(
            kmx.reshape(BHG, nb, b), y_idx[..., None], axis=1
        )  # (BHG, m, b)
    mask = _block_mask(flags, km_blk, b)

    # per-token stabilizer: scatter-max of masked block row maxima over the
    # floor c (the caller's coarse background max)
    row_max = jnp.max(jnp.where(mask, s, NEG_INF), axis=-1)  # (BHG, m, b)
    base = jnp.broadcast_to(c[..., None], (BHG, nb, b)).astype(jnp.float32)
    mt = jax.vmap(lambda z, i, u: z.at[i].max(u))(base, x_idx, row_max)
    mt = jax.lax.stop_gradient(mt)

    mt_sel = jnp.take_along_axis(mt, x_idx[..., None], axis=1)  # (BHG, m, b)
    # valid entries satisfy s ≤ mt by construction, so no clamp is needed —
    # and a clamp would corrupt autodiff with a ½-gradient at the row-max tie.
    # Masked lanes are sanitized *before* exp (the where-grad 0·inf guard).
    arg = jnp.where(mask, s - mt_sel[..., None], 0.0)
    a = jnp.where(mask, jnp.exp(arg), 0.0)
    return a, q_blk, k_blk, mt


def block_sparse_attention_ref(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    x_idx: jax.Array,  # (BHG, m)
    y_idx: jax.Array,  # (BHG, m)
    flags: jax.Array,  # (BHG, m) bit0 valid, bit1 causal-diag
    c: jax.Array,  # (BHG, nb) stabilizer floor
    key_mask: Optional[jax.Array] = None,  # (BHKV, n), >0 = valid key
    *,
    scale: float,
    block_size: int,
):
    """Returns (out (BHG,n,d), rowsum (BHG,n), mt (BHG,n)), all fp32."""
    BHG, n, d = q.shape
    G = BHG // k.shape[0]
    b = block_size
    nb = n // b

    a, _, _, mt = _recompute(
        q, k, c, x_idx, y_idx, flags, key_mask, scale=scale, block_size=b
    )
    v_blk = _gather_blocks(_expand_kv(v, G).astype(jnp.float32), y_idx, b)

    o_blk = jnp.einsum("rmij,rmjd->rmid", a, v_blk)
    r_blk = jnp.sum(a, axis=-1)

    seg = jax.vmap(lambda z, i, u: z.at[i].add(u))
    out = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), x_idx, o_blk).reshape(BHG, n, d)
    rowsum = seg(jnp.zeros((BHG, nb, b), jnp.float32), x_idx, r_blk).reshape(BHG, n)
    return out, rowsum, mt.reshape(BHG, n)


def block_sparse_attention_bwd_ref(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    c: jax.Array,  # (BHG, nb)
    x_idx: jax.Array,  # (BHG, m)
    y_idx: jax.Array,  # (BHG, m)
    flags: jax.Array,  # (BHG, m)
    key_mask: Optional[jax.Array],  # (BHKV, n) or None
    do: jax.Array,  # (BHG, n, d) cotangent of the numerator
    dr: jax.Array,  # (BHG, n) cotangent of the row sums
    *,
    scale: float,
    block_size: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-style recompute backward: (dq, dk, dv), all fp32.

    Per selected pair (x, y):  s = q_x k_y^T·scale,  a = mask·exp(s − mt_x),
    da = do_x v_y^T + dr_x 1^T,  ds = a ⊙ da, then
      dq_x += ds k_y·scale,   dk_y += ds^T q_x·scale  (G-group reduced),
      dv_y += a^T do_x.   dc ≡ 0 (stabilizer is gradient-transparent).
    """
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    G = BHG // BHKV
    b = block_size
    nb = n // b

    a, q_blk, k_blk, _ = _recompute(
        q, k, c, x_idx, y_idx, flags, key_mask, scale=scale, block_size=b
    )
    v_blk = _gather_blocks(_expand_kv(v, G).astype(jnp.float32), y_idx, b)

    do_blk = _gather_blocks(do.astype(jnp.float32), x_idx, b)
    dr_blk = jnp.take_along_axis(
        dr.reshape(BHG, nb, b).astype(jnp.float32), x_idx[..., None], axis=1
    )
    da = jnp.einsum("rmid,rmjd->rmij", do_blk, v_blk) + dr_blk[..., None]
    ds = a * da

    dq_blk = jnp.einsum("rmij,rmjd->rmid", ds, k_blk) * scale
    dk_blk = jnp.einsum("rmij,rmid->rmjd", ds, q_blk) * scale
    dv_blk = jnp.einsum("rmij,rmid->rmjd", a, do_blk)

    seg = jax.vmap(lambda z, i, u: z.at[i].add(u))
    dq = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), x_idx, dq_blk).reshape(BHG, n, d)
    dkx = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), y_idx, dk_blk)
    dvx = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), y_idx, dv_blk)
    dk = jnp.sum(dkx.reshape(BHKV, G, nb, b, d), axis=1).reshape(BHKV, n, d)
    dv = jnp.sum(dvx.reshape(BHKV, G, nb, b, d), axis=1).reshape(BHKV, n, d)
    return dq, dk, dv
