"""Pallas TPU kernels: data-dependent block-sparse attention, fwd + bwd.

This is the TPU-native replacement for the paper's custom CUDA block-sparsity
kernels (paper §6: "Overcoming this limitation required implementing custom
CUDA kernels for some generic block sparsity operators").

Design (DESIGN.md §3):
  * Selected (query-block, key-block) index pairs live in SMEM via
    ``PrefetchScalarGridSpec`` — the BlockSpec ``index_map`` performs the
    data-dependent HBM→VMEM DMA, replacing CUDA thread-level gathers.
  * The grid is ``(rows, pairs)``; the wrapper sorts block pairs by the block
    id that addresses the *output* tile (query block for fwd/dq, key block
    for dk/dv) so revisits of the same tile are consecutive — Pallas keeps
    the accumulator tiles resident in VMEM between consecutive grid steps
    that map to the same block (the sequential-grid equivalent of CUDA
    atomics).
  * Flash-style online softmax: the forward keeps a per-token running max
    ``mt`` (seeded with the coarse background max ``c`` as a floor) and
    rescales the resident numerator/row-sum tiles when a new block raises
    it. Attention weights never exceed exp(0) = 1, so neither the forward
    nor the recompute backward can overflow fp32 — the property that makes
    the kernel trainable. ``mt`` is emitted so the caller can align the
    MRA-2 coarse background with the exact same per-token stabilizer the
    pure-jnp path uses (core/mra.py); it is gradient-transparent by
    contract (stabilizers cancel in the normalized output).
  * GQA without KV expansion: K/V are indexed at ``bhg // group`` in the
    ``index_map`` so grouped query heads share the KV tiles in HBM. The
    backward dk/dv kernel instead flattens each KV head's G groups of pairs
    into one sorted-by-key-block list, so the G-way gradient reduction is a
    by-product of the same resident-tile accumulation.
  * Key-padding masks ride along as a per-key-block (1, b) VMEM tile, so
    ``use_kernel=True`` serves arbitrary (padded) sequence lengths.
  * The backward is a flash-style recompute: no O(m·b²) attention weights
    are saved; both bwd kernels rebuild ``a = mask·exp(qk·scale − mt)``
    from the forward residuals inside the kernel.
  * fp32 accumulation regardless of input dtype (MXU-native
    ``preferred_element_type``).

Forward outputs are the *unnormalized* block-sparse numerator, the row sums,
and the per-token stabilizer; the caller divides (and adds the MRA-2 coarse
background) outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.mra import NEG_INF  # shared finite "minus infinity" sentinel


def _block_mask(flags, km, b):
    """(b, b) boolean mask for one score tile.

    flags bit0: pair valid; bit1: causal triangular mask (diagonal block).
    km (b,) fp32 > 0 marks valid keys (columns).
    """
    valid = (flags & 1) == 1
    diag = (flags & 2) == 2
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    tri_ok = rows >= cols
    mask = jnp.where(diag, tri_ok, jnp.ones_like(tri_ok))
    mask = mask & jnp.broadcast_to(valid, (b, b))
    return mask & jnp.broadcast_to((km > 0)[None, :], (b, b))


def _dot(a, b_, dims):
    return jax.lax.dot_general(a, b_, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _recompute_weights(q_ref, k_ref, mt_ref, flags, km_ref, scale, b):
    """Backward-pass recompute of a = mask·exp(s − mt) for one block pair.

    mt is the forward's final per-token stabilizer, an upper bound of every
    visited score, so the exp argument is ≤ 0 — weights cannot overflow.
    """
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = _dot(q, k, ((1,), (1,))) * scale - mt_ref[0][:, None]
    mask = _block_mask(flags, km_ref[0], b)
    return jnp.where(mask, jnp.exp(jnp.minimum(s, 0.0)), 0.0), q, k


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #
def _fwd_kernel(
    # scalar prefetch (SMEM)
    x_idx_ref,  # (BHG, m) query-block ids, sorted per bhg
    y_idx_ref,  # (BHG, m) key-block ids
    first_ref,  # (BHG, m) 1 when this grid step first visits its output tile
    flags_ref,  # (BHG, m) bit0: block valid; bit1: apply causal tri mask
    # VMEM tiles
    q_ref,  # (1, b, d)
    k_ref,  # (1, b, d)
    v_ref,  # (1, b, d)
    c_ref,  # (1, 1) stabilizer floor for this query block (coarse bg max)
    km_ref,  # (1, b) key validity for this key block
    o_ref,  # (1, b, d) accumulated numerator (stabilized by mt)
    r_ref,  # (1, b) accumulated row sums
    mt_ref,  # (1, b) running per-token max stabilizer
    *,
    scale: float,
    block_size: int,
):
    bhg = pl.program_id(0)
    i = pl.program_id(1)
    b = block_size

    @pl.when(first_ref[bhg, i] == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        r_ref[...] = jnp.zeros_like(r_ref)
        mt_ref[...] = jnp.zeros_like(mt_ref) + c_ref[0, 0]

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = _dot(q, k, ((1,), (1,))) * scale
    mask = _block_mask(flags_ref[bhg, i], km_ref[0], b)

    # online rescale (flash-attention): raise the running max, shrink the
    # resident accumulators, then add this block at the new stabilizer.
    m_old = mt_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(jnp.where(mask, s, NEG_INF), axis=1))
    alpha = jnp.exp(m_old - m_new)  # ≤ 1
    # valid entries have s ≤ m_new by construction; the min guards the
    # masked lanes from computing exp(+large) → inf before the where
    a = jnp.where(mask, jnp.exp(jnp.minimum(s - m_new[:, None], 0.0)), 0.0)

    o_ref[0] = o_ref[0] * alpha[:, None] + _dot(a, v, ((1,), (0,)))
    r_ref[0] = r_ref[0] * alpha + jnp.sum(a, axis=1)
    mt_ref[0] = m_new


def block_sparse_attention_fwd(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    x_idx: jax.Array,  # (BHG, m) int32, sorted ascending per row
    y_idx: jax.Array,  # (BHG, m) int32
    first: jax.Array,  # (BHG, m) int32 first-visit flags
    flags: jax.Array,  # (BHG, m) int32 bit0 valid, bit1 causal-diag
    c: jax.Array,  # (BHG, nb) fp32 stabilizer floor (> NEG_INF/2 clamped)
    km: jax.Array,  # (BHKV, n) fp32, >0 = valid key
    *,
    scale: float,
    block_size: int,
    interpret: bool = False,
):
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    group = BHG // BHKV
    m = x_idx.shape[1]
    b = block_size

    kernel = functools.partial(_fwd_kernel, scale=scale, block_size=b)
    out_shapes = (
        jax.ShapeDtypeStruct((BHG, n, d), jnp.float32),
        jax.ShapeDtypeStruct((BHG, n), jnp.float32),
        jax.ShapeDtypeStruct((BHG, n), jnp.float32),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(BHG, m),
        in_specs=[
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i], 0)),
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg // group, yi[bhg, i], 0)),
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg // group, yi[bhg, i], 0)),
            pl.BlockSpec((1, 1), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i])),
            pl.BlockSpec((1, b), lambda bhg, i, xi, yi, fi, fl: (bhg // group, yi[bhg, i])),
        ],
        out_specs=[
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i], 0)),
            pl.BlockSpec((1, b), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i])),
            pl.BlockSpec((1, b), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i])),
        ],
    )
    out, rowsum, mt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(x_idx, y_idx, first, flags, q, k, v, c, km)
    return out, rowsum, mt


# --------------------------------------------------------------------------- #
# Backward, kernel 1: dq (pairs sorted by query block)
# --------------------------------------------------------------------------- #
def _bwd_dq_kernel(
    x_idx_ref, y_idx_ref, first_ref, flags_ref,  # SMEM, all (BHG, M1)
    q_ref,   # (1, b, d)
    k_ref,   # (1, b, d)
    v_ref,   # (1, b, d)
    mt_ref,  # (1, b) forward per-token stabilizer for this query block
    do_ref,  # (1, b, d) numerator cotangent tile
    dr_ref,  # (1, b) row-sum cotangent tile
    km_ref,  # (1, b)
    dq_ref,  # (1, b, d) out
    *,
    scale: float,
    block_size: int,
):
    bhg = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(first_ref[bhg, i] == 1)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    a, _, k = _recompute_weights(
        q_ref, k_ref, mt_ref, flags_ref[bhg, i], km_ref, scale, block_size
    )
    do = do_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    # da[i,j] = <do_i, v_j> + dr_i ; ds = a ⊙ da  (softmax-free: the
    # normalization lives outside the kernel contract)
    ds = a * (_dot(do, v, ((1,), (1,))) + dr_ref[0][:, None])
    dq_ref[0] += _dot(ds, k, ((1,), (0,))) * scale


# --------------------------------------------------------------------------- #
# Backward, kernel 2: dk + dv (pairs flattened per KV head, sorted by key
# block; the G-way GQA reduction happens via consecutive accumulation)
# --------------------------------------------------------------------------- #
def _bwd_dkv_kernel(
    row_ref, x_idx_ref, y_idx_ref, first_ref, flags_ref,  # SMEM, all (BHKV, M2)
    q_ref,   # (1, b, d) query block of the owning BHG row
    k_ref,   # (1, b, d)
    v_ref,   # (1, b, d)
    mt_ref,  # (1, b)
    do_ref,  # (1, b, d)
    dr_ref,  # (1, b)
    km_ref,  # (1, b)
    dk_ref,  # (1, b, d) out
    dv_ref,  # (1, b, d) out
    *,
    scale: float,
    block_size: int,
):
    kv = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(first_ref[kv, i] == 1)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    a, q, _ = _recompute_weights(
        q_ref, k_ref, mt_ref, flags_ref[kv, i], km_ref, scale, block_size
    )
    do = do_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    ds = a * (_dot(do, v, ((1,), (1,))) + dr_ref[0][:, None])

    dk_ref[0] += _dot(ds, q, ((0,), (0,))) * scale  # ds^T q
    dv_ref[0] += _dot(a, do, ((0,), (0,)))  # a^T do


def block_sparse_attention_bwd(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    mt: jax.Array,  # (BHG, n) forward per-token stabilizer
    do: jax.Array,  # (BHG, n, d)
    dr: jax.Array,  # (BHG, n)
    km: jax.Array,  # (BHKV, n) fp32
    # pairs sorted by query block (dq pass), (BHG, M1) each
    xq: jax.Array, yq: jax.Array, firstq: jax.Array, flagsq: jax.Array,
    # pairs flattened per KV head and sorted by key block (dk/dv pass),
    # (BHKV, M2) each; rowk[kv, i] is the owning BHG row of pair i
    rowk: jax.Array, xk: jax.Array, yk: jax.Array, firstk: jax.Array,
    flagsk: jax.Array,
    *,
    scale: float,
    block_size: int,
    interpret: bool = False,
):
    """Fused backward: (dq, dk, dv), all fp32.

    The stabilizer is gradient-transparent (DESIGN.md §3): dc ≡ 0 by the
    kernel contract, so no dc pass exists.

    Contract: every query block id must appear in ``xq`` and every key block
    id in ``yk`` at least once per row (invalid pairs count) — unvisited
    output tiles are never initialized. ``ops._bwd`` guarantees this by
    padding the pair list with one invalid pair per block id.
    """
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    group = BHG // BHKV
    b = block_size
    M1 = xq.shape[1]
    M2 = xk.shape[1]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, block_size=b),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(BHG, M1),
            in_specs=[
                pl.BlockSpec((1, b, d), lambda g, i, xi, yi, fi, fl: (g, xi[g, i], 0)),
                pl.BlockSpec((1, b, d), lambda g, i, xi, yi, fi, fl: (g // group, yi[g, i], 0)),
                pl.BlockSpec((1, b, d), lambda g, i, xi, yi, fi, fl: (g // group, yi[g, i], 0)),
                pl.BlockSpec((1, b), lambda g, i, xi, yi, fi, fl: (g, xi[g, i])),
                pl.BlockSpec((1, b, d), lambda g, i, xi, yi, fi, fl: (g, xi[g, i], 0)),
                pl.BlockSpec((1, b), lambda g, i, xi, yi, fi, fl: (g, xi[g, i])),
                pl.BlockSpec((1, b), lambda g, i, xi, yi, fi, fl: (g // group, yi[g, i])),
            ],
            out_specs=[
                pl.BlockSpec((1, b, d), lambda g, i, xi, yi, fi, fl: (g, xi[g, i], 0)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((BHG, n, d), jnp.float32)],
        interpret=interpret,
    )(xq, yq, firstq, flagsq, q, k, v, mt, do, dr, km)[0]

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, block_size=b),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(BHKV, M2),
            in_specs=[
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (ro[kv, i], xi[kv, i], 0)),
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (kv, yi[kv, i], 0)),
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (kv, yi[kv, i], 0)),
                pl.BlockSpec((1, b), lambda kv, i, ro, xi, yi, fi, fl: (ro[kv, i], xi[kv, i])),
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (ro[kv, i], xi[kv, i], 0)),
                pl.BlockSpec((1, b), lambda kv, i, ro, xi, yi, fi, fl: (ro[kv, i], xi[kv, i])),
                pl.BlockSpec((1, b), lambda kv, i, ro, xi, yi, fi, fl: (kv, yi[kv, i])),
            ],
            out_specs=[
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (kv, yi[kv, i], 0)),
                pl.BlockSpec((1, b, d), lambda kv, i, ro, xi, yi, fi, fl: (kv, yi[kv, i], 0)),
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((BHKV, n, d), jnp.float32),
            jax.ShapeDtypeStruct((BHKV, n, d), jnp.float32),
        ),
        interpret=interpret,
    )(rowk, xk, yk, firstk, flagsk, q, k, v, mt, do, dr, km)

    return dq, dk, dv
