"""Pallas TPU kernel: data-dependent block-sparse attention (MRA-2 high-res).

This is the TPU-native replacement for the paper's custom CUDA block-sparsity
kernels (paper §6: "Overcoming this limitation required implementing custom
CUDA kernels for some generic block sparsity operators").

Design (DESIGN.md §3):
  * Selected (query-block, key-block) index pairs live in SMEM via
    ``PrefetchScalarGridSpec`` — the BlockSpec ``index_map`` performs the
    data-dependent HBM→VMEM DMA, replacing CUDA thread-level gathers.
  * The grid is ``(BHG, m)``; the wrapper sorts block pairs by query block so
    revisits of the same output tile are consecutive — Pallas keeps the
    accumulator tile resident in VMEM between consecutive grid steps that map
    to the same block (the sequential-grid equivalent of CUDA atomics).
  * GQA without KV expansion: K/V are indexed at ``bhg // group`` in the
    ``index_map`` so grouped query heads share the KV tiles in HBM.
  * fp32 accumulation regardless of input dtype (MXU-native
    ``preferred_element_type``).

Outputs are the *unnormalized* block-sparse numerator and the row sums; the
caller divides (and adds the MRA-2 coarse background) outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(
    # scalar prefetch (SMEM)
    x_idx_ref,  # (BHG, m) query-block ids, sorted per bhg
    y_idx_ref,  # (BHG, m) key-block ids
    first_ref,  # (BHG, m) 1 when this grid step first visits its output tile
    flags_ref,  # (BHG, m) bit0: block valid; bit1: apply causal tri mask
    # VMEM tiles
    q_ref,  # (1, b, d)
    k_ref,  # (1, b, d)
    v_ref,  # (1, b, d)
    c_ref,  # (1, 1) stabilizer for this query block
    o_ref,  # (1, b, d) accumulated numerator
    r_ref,  # (1, b) accumulated row sums
    *,
    scale: float,
    block_size: int,
):
    bhg = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(first_ref[bhg, i] == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        r_ref[...] = jnp.zeros_like(r_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale - c_ref[0, 0]

    flags = flags_ref[bhg, i]
    valid = (flags & 1) == 1
    diag = (flags & 2) == 2
    b = block_size
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    tri_ok = rows >= cols
    mask = jnp.where(diag, tri_ok, jnp.ones_like(tri_ok)) & jnp.broadcast_to(valid, (b, b))
    # exp clamp: the block-level stabilizer c can undershoot the true row max
    # (numerical-range r, paper Lemma 4.1); clamping keeps fp32 finite.
    a = jnp.where(mask, jnp.exp(jnp.minimum(s, 80.0)), 0.0)

    o_ref[0] += jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    r_ref[0] += jnp.sum(a, axis=1)


def block_sparse_attention_fwd(
    q: jax.Array,  # (BHG, n, d)
    k: jax.Array,  # (BHKV, n, d)
    v: jax.Array,  # (BHKV, n, d)
    x_idx: jax.Array,  # (BHG, m) int32, sorted ascending per row
    y_idx: jax.Array,  # (BHG, m) int32
    first: jax.Array,  # (BHG, m) int32 first-visit flags
    flags: jax.Array,  # (BHG, m) int32 bit0 valid, bit1 causal-diag
    c: jax.Array,  # (BHG, nb) fp32 per-query-block stabilizer
    *,
    scale: float,
    block_size: int,
    interpret: bool = False,
):
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    group = BHG // BHKV
    m = x_idx.shape[1]
    b = block_size
    nb = n // b

    grid = (BHG, m)
    kernel = functools.partial(_kernel, scale=scale, block_size=b)
    out_shapes = (
        jax.ShapeDtypeStruct((BHG, n, d), jnp.float32),
        jax.ShapeDtypeStruct((BHG, n), jnp.float32),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i], 0)),
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg // group, yi[bhg, i], 0)),
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg // group, yi[bhg, i], 0)),
            pl.BlockSpec((1, 1), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i])),
        ],
        out_specs=[
            pl.BlockSpec((1, b, d), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i], 0)),
            pl.BlockSpec((1, b), lambda bhg, i, xi, yi, fi, fl: (bhg, xi[bhg, i])),
        ],
    )
    q3 = q.reshape(BHG, nb, b, d).reshape(BHG, n, d)  # no-op; keep layout explicit
    out, rowsum = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(x_idx, y_idx, first, flags, q3, k, v, c)
    return out, rowsum
