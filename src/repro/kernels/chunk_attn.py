"""Pallas TPU kernel: ring-paged chunk/decode MRA attention for serving.

This is the serving-side twin of the training kernels in
``block_sparse_attn.py`` (DESIGN.md §11). Everything after the shared page
statistics — coarse page scoring, the causal block mask, own-block force
selection, top-m selection, the gathered exact term, the coarse pyramid
background and the final normalization — runs *inside one kernel*:

  * in-kernel selection — the coarse scores ``q · k̄_y · scale`` are an
    MXU matmul against the resident ``k_ds`` page-means tile, so the
    ``(B, Hkv, G, C, nb)`` coarse-score tensor never exists in HBM and the
    separate ``jax.lax.top_k`` pass disappears. Top-m is m static rounds of
    (row-max, lowest-column-among-ties) — exactly ``jax.lax.top_k``'s
    first-index tie-break — masked to the *valid* pages
    (live ∧ causally allowed); the query's own live block is force-selected
    via the shared FORCE_BONUS, matching the jnp oracle bit-for-bit in
    which pages get selected.
  * MXU-shaped tiles — the grid is ``(B·Hkv, C/C_tile)``: each step scores a
    ``(G·C_tile, b)`` tile per page and a ``(G·C_tile, nb)`` coarse tile,
    real matmuls instead of the old single-query-row dots.
  * gather by manual DMA — selected K/V pages are copied HBM→VMEM with
    ``pltpu.make_async_copy`` from ``ANY``-space cache refs, one
    ``pl.when``-guarded fetch per page in the selection union, fused with a
    flash-style online softmax (running per-row max, rescaled accumulators)
    and the exact ``pos_k <= q_pos`` mask. No ``(…, m, b, D)`` gather tensor
    ever reaches HBM. int8 pages are dequantized in VMEM from per-token
    scale slices.
  * background + normalize — the coarse background
    ``Σ_bg exp(μ − c)·count_y · v̄_y`` is a ``(rows, nb) @ (nb, D)`` matmul
    against the resident ``v_ds`` tile, aligned onto the two-level
    stabilizer ``c_tok = max(c, fine_max)``; the normalized output is
    emitted directly (all-masked rows → exact zeros).
  * H-level far field (DESIGN.md §14) — when the cache is hierarchical
    (``levels >= 3``), the collapsed-level + tail means arrive as two more
    resident ``(NU, D)`` tiles with an (NU,) count row; the fold is one
    extra ``(rows, NU)`` score matmul + ``(rows, NU) @ (NU, D)`` background
    matmul inside the same stabilizer. Selection stays in-kernel and
    untouched — the hierarchy only widens the background. At levels == 2
    the operands are static dummies and the fold is compiled out, keeping
    the two-level program identical.

Dual mode (DESIGN.md §11): the same body is instantiated at two static
query-tile widths, selected per dispatch —

  * ``latency``    — C_tile = 1: one wave per (batch·kv-head) row; minimal
    work per step, the decode (C == 1) shape.
  * ``throughput`` — C_tile = min(C, 8): multi-query tiles for verify
    chunks and chunked prefill; the MXU sees (G·C_tile, ·) operands.

``mode="auto"`` resolves at trace time (C == 1 → latency, else throughput),
which is how the engine picks per dispatch: decode waves trace with C == 1,
prefill/verify chunks with C == chunk. ``EngineConfig.kernel_mode`` forces
one mode for every dispatch. Ragged chunks (C not a multiple of C_tile) are
padded with ``q_pos = -1`` rows, which select nothing and are sliced off.

Forward-only by design: the serving path is never differentiated (training
uses the §3 kernels). Differentiating through this op raises at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.mra import NEG_INF, FORCE_BONUS

KERNEL_MODES = ("auto", "latency", "throughput")
THROUGHPUT_C_TILE = 8  # query-tile width of the throughput instantiation
# removal sentinel for already-picked selection entries: strictly below
# NEG_INF so a picked page can never win a later round, and below any
# masked-off score so exhausted rows keep re-picking an already-dead column
_PICKED = -2e9


def resolve_kernel_mode(mode: str, C: int) -> str:
    """'auto' → latency for single-query (decode) traces, else throughput."""
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"kernel_mode must be one of {KERNEL_MODES}, got {mode!r}")
    if mode == "auto":
        return "latency" if C == 1 else "throughput"
    return mode


def _dot(a, b_, dims):
    return jax.lax.dot_general(a, b_, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _chunk_kernel(
    # VMEM tiles
    q_ref,       # (1, G, Ct, D) query tile (fp32)
    qpos_ref,    # (1, G, Ct, 1) int32 global positions (-1 = padded row)
    kds_ref,     # (1, nb, D) per-page K means (coarse scoring keys)
    vds_ref,     # (1, nb, D) per-page V means (coarse background values)
    counts_ref,  # (1, nb) f32 valid tokens per page
    pb_ref,      # (1, nb) int32 page table row (logical block, -1 dead)
    hk_ref,      # (1, NU, D) f32 collapsed-level + tail K means (§14);
                 # (1, 1, D) zero dummy when with_upper is False
    hv_ref,      # (1, NU, D) f32 collapsed-level + tail V means
    hcnt_ref,    # (1, NU) f32 per-entry token counts (0 = dead entry)
    # ANY-space refs (manual DMA sources)
    k_any,       # (BKV, nb, b, D) cache dtype
    v_any,       # (BKV, nb, b, D)
    ks_any,      # (BKV, nb, b, 1) f32 dequant scales ((1,1,1,1) dummy)
    vs_any,      # (BKV, nb, b, 1)
    # output
    o_ref,       # (1, G, Ct, D) f32
    # scratch
    kpage,       # (b, D) VMEM landing pad for one K page
    vpage,       # (b, D)
    kspage,      # (b, 1) per-token K scales for the page
    vspage,      # (b, 1)
    sems,        # (4,) DMA semaphores
    acc_ref,     # (rows, D) f32 online-softmax numerator
    rs_ref,      # (rows, 1) f32 row sum
    mt_ref,      # (rows, 1) f32 running fine-score max
    *,
    scale: float,
    block_size: int,
    m: int,
    quant: bool,
    include_bg: bool,
    with_upper: bool,
):
    r = pl.program_id(0)
    b = block_size
    _, G, Ct, D = q_ref.shape
    nb = kds_ref.shape[1]
    rows = G * Ct

    q = q_ref[0].reshape(rows, D)                 # fp32 query tile
    qp = qpos_ref[0].reshape(rows, 1)             # int32, lane dim kept
    kds = kds_ref[0]                              # (nb, D)
    pbrow = pb_ref[...]                           # (1, nb)
    cnt = counts_ref[...]                         # (1, nb)

    # ---- in-kernel coarse scores + causal/validity masks -------------------
    coarse = _dot(q, kds, ((1,), (1,))) * scale   # (rows, nb) — MXU matmul
    jq = qp // b                                  # query block (−1 for pads)
    live = cnt > 0.0
    allowed = live & (pbrow <= jq)                # live past+own pages
    ownl = (pbrow == jq) & (pbrow >= 0) & live    # query's own live block
    # a page is a valid exact-attention target iff causally allowed and live
    # (own ⊆ allowed when live); dead own blocks are NOT force-selected —
    # the selection-validity contract shared with the jnp oracle.
    coarse_m = jnp.where(allowed, coarse, NEG_INF)
    selsc = coarse_m + FORCE_BONUS * ownl.astype(jnp.float32)

    # ---- in-kernel top-m: m rounds of (row max, first column among ties) ---
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, nb), 1)
    sel_grid = jnp.zeros((rows, nb), dtype=bool)
    for _ in range(m):
        val = jnp.max(selsc, axis=1, keepdims=True)
        pick = jnp.min(jnp.where(selsc == val, col, nb), axis=1, keepdims=True)
        one = col == pick
        sel_grid = sel_grid | (one & allowed)     # invalid picks select nothing
        selsc = jnp.where(one, _PICKED, selsc)

    # ---- exact term: DMA-gather the selection union, online softmax --------
    acc_ref[...] = jnp.zeros_like(acc_ref)
    rs_ref[...] = jnp.zeros_like(rs_ref)
    mt_ref[...] = jnp.zeros_like(mt_ref) + NEG_INF
    col1 = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)
    sel_any = jnp.max(sel_grid.astype(jnp.float32), axis=0, keepdims=True)

    def page_body(j, _):
        picked = jnp.sum(jnp.where(col1 == j, sel_any, 0.0)) > 0.0

        @pl.when(picked)
        def _fetch_and_accumulate():
            cp_k = pltpu.make_async_copy(k_any.at[r, j], kpage, sems.at[0])
            cp_v = pltpu.make_async_copy(v_any.at[r, j], vpage, sems.at[1])
            cp_k.start()
            cp_v.start()
            if quant:
                cp_ks = pltpu.make_async_copy(ks_any.at[r, j], kspage,
                                              sems.at[2])
                cp_vs = pltpu.make_async_copy(vs_any.at[r, j], vspage,
                                              sems.at[3])
                cp_ks.start()
                cp_vs.start()
            cp_k.wait()
            cp_v.wait()
            k = kpage[...].astype(jnp.float32)
            vv = vpage[...].astype(jnp.float32)
            if quant:  # int8 pages: dequantize in VMEM from per-token scales
                cp_ks.wait()
                cp_vs.wait()
                k = k * kspage[...]
                vv = vv * vspage[...]
            s = _dot(q, k, ((1,), (1,))) * scale          # (rows, b) on MXU
            blk = jnp.sum(jnp.where(col1 == j, pbrow, 0))  # logical block id
            pos = blk * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
            selcol = jnp.max(
                jnp.where(col1 == j, sel_grid.astype(jnp.float32), 0.0),
                axis=1, keepdims=True) > 0.0              # (rows, 1)
            ok = selcol & (pos >= 0) & (pos <= qp)
            # flash-style online stabilization: raise the running max, shrink
            # the resident accumulators, add this page at the new max
            m_old = mt_ref[...]
            m_new = jnp.maximum(
                m_old, jnp.max(jnp.where(ok, s, NEG_INF), axis=1,
                               keepdims=True))
            alpha = jnp.exp(m_old - m_new)
            a = jnp.where(ok, jnp.exp(s - m_new), 0.0)
            acc_ref[...] = acc_ref[...] * alpha + _dot(a, vv, ((1,), (0,)))
            rs_ref[...] = rs_ref[...] * alpha + jnp.sum(a, axis=1,
                                                        keepdims=True)
            mt_ref[...] = m_new

        return 0

    jax.lax.fori_loop(0, nb, page_body, 0)

    # ---- background + two-level stabilizer + normalize ---------------------
    c = jnp.maximum(jnp.max(coarse_m, axis=1, keepdims=True), NEG_INF * 0.5)
    if include_bg and with_upper:
        # H-level hierarchy (DESIGN.md §14): score the resident collapsed-
        # level + tail means. Entries hold only evicted (strictly past)
        # tokens — liveness is the one gate — and their maxima join the row
        # stabilizer before any exp: far history can dominate the window.
        hmu = _dot(q, hk_ref[0], ((1,), (1,))) * scale   # (rows, NU)
        hlive = hcnt_ref[...] > 0.0                      # (1, NU)
        hmu = jnp.where(hlive, hmu, NEG_INF)
        c = jnp.maximum(c, jnp.max(hmu, axis=1, keepdims=True))
    mt = mt_ref[...]
    c_tok = jnp.maximum(c, mt)                    # two-level stabilizer
    fine_adj = jnp.exp(mt - c_tok)                # mt ≤ c_tok, so ≤ 1
    out = acc_ref[...] * fine_adj
    rs = rs_ref[...] * fine_adj
    if include_bg:  # MRA-2 "full": coarse pyramid background
        bg = allowed & ~ownl & ~sel_grid
        w = jnp.where(bg, jnp.exp(coarse_m - c), 0.0) * cnt
        adj = jnp.exp(c - c_tok)
        vds = vds_ref[0]                          # (nb, D)
        out = out + adj * _dot(w, vds, ((1,), (0,)))   # (rows, nb)@(nb, D)
        rs = rs + adj * jnp.sum(w, axis=1, keepdims=True)
        if with_upper:
            wh = jnp.where(hlive, jnp.exp(hmu - c), 0.0) * hcnt_ref[...]
            out = out + adj * _dot(wh, hv_ref[0], ((1,), (0,)))
            rs = rs + adj * jnp.sum(wh, axis=1, keepdims=True)
    alive = rs > 0.0
    o = jnp.where(alive, out, 0.0) / jnp.where(alive, rs, 1.0)
    o_ref[0] = o.reshape(G, Ct, D)


def _no_grad(*args, **kw):
    raise NotImplementedError(
        "mra2 chunk/decode kernel is forward-only (serving path); training "
        "differentiates through the §3 block-sparse kernels instead")


@functools.partial(
    jax.custom_jvp, nondiff_argnums=(13, 14, 15, 16, 17, 18, 19, 20))
def _chunk_attention_call(
    q4, qpos4, kds3, vds3, counts2, pb2, hk3, hv3, hcnt2, k4, v4, ks4, vs4,
    scale, block_size, m, c_tile, quant, include_bg, with_upper, interpret,
):
    """pallas_call entry. q4 (BKV, G, Cp, D) fp32; qpos4 (BKV, G, Cp, 1)
    int32 (−1 = padded row); kds3/vds3 (BKV, nb, D) fp32; counts2/pb2
    (B, nb); hk3/hv3 (BKV, NU, D) fp32 collapsed-level + tail means with
    hcnt2 (B, NU) counts when ``with_upper`` (zero (1, 1, D)/(1, 1) dummies
    otherwise — the fold is statically skipped); k4/v4 (BKV, nb, b, D)
    cache dtype; ks4/vs4 (BKV, nb, b, 1) fp32 scales ((1, 1, 1, 1) dummies
    when not ``quant``). ``Cp`` must be a multiple of the static query-tile
    width ``c_tile``."""
    BKV, G, Cp, D = q4.shape
    nb, b = k4.shape[1], k4.shape[2]
    B = counts2.shape[0]
    hkv = BKV // B
    rows = G * c_tile
    nu = hk3.shape[1]

    kernel = functools.partial(
        _chunk_kernel, scale=scale, block_size=b, m=m, quant=quant,
        include_bg=include_bg, with_upper=with_upper)
    grid = (BKV, Cp // c_tile)
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    if with_upper:  # resident tiles, one row per (batch·kv-head) like kds
        hmean_spec = pl.BlockSpec((1, nu, D), lambda r, t: (r, 0, 0))
        hcnt_spec = pl.BlockSpec((1, nu), lambda r, t: (r // hkv, 0))
    else:  # single shared dummy tile, never read
        hmean_spec = pl.BlockSpec((1, 1, D), lambda r, t: (0, 0, 0))
        hcnt_spec = pl.BlockSpec((1, 1), lambda r, t: (0, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, c_tile, D), lambda r, t: (r, 0, t, 0)),
            pl.BlockSpec((1, G, c_tile, 1), lambda r, t: (r, 0, t, 0)),
            pl.BlockSpec((1, nb, D), lambda r, t: (r, 0, 0)),
            pl.BlockSpec((1, nb, D), lambda r, t: (r, 0, 0)),
            pl.BlockSpec((1, nb), lambda r, t: (r // hkv, 0)),
            pl.BlockSpec((1, nb), lambda r, t: (r // hkv, 0)),
            hmean_spec,
            hmean_spec,
            hcnt_spec,
            any_spec,  # K pages: fetched by explicit per-page DMA
            any_spec,
            any_spec,
            any_spec,
        ],
        out_specs=pl.BlockSpec((1, G, c_tile, D), lambda r, t: (r, 0, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, Cp, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((b, D), k4.dtype),
            pltpu.VMEM((b, D), v4.dtype),
            pltpu.VMEM((b, 1), jnp.float32),
            pltpu.VMEM((b, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((4,)),
            pltpu.VMEM((rows, D), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            # grid steps are fully independent (no cross-step accumulators),
            # so the (batch·kv-head) axis may run on both megacore cores; the
            # chunk-tile axis stays sequential to keep kds/vds tiles resident
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q4, qpos4, kds3, vds3, counts2, pb2, hk3, hv3, hcnt2, k4, v4, ks4, vs4)
    return out


_chunk_attention_call.defjvp(_no_grad)


def chunk_attention_kernel(
    pre,
    k_cache: jax.Array,
    v_cache: jax.Array,
    q_pos: jax.Array,
    *,
    m: int,
    k_scale=None,
    v_scale=None,
    include_bg: bool = True,
    interpret: bool = False,
    mode: str = "auto",
) -> jax.Array:
    """Fused chunk/decode attention from the shared page-stats prelude.

    ``pre`` is ``core.mra_decode.ChunkPrelude`` (grouped queries + page
    table/counts + k_ds/v_ds page means) — selection itself happens inside
    the kernel. ``m`` is the static top-m budget, ``mode`` one of
    ``{"auto", "latency", "throughput"}`` (see ``resolve_kernel_mode``).
    Returns (B, Hq, C, D) fp32; the caller casts to q.dtype.
    """
    B, Hkv, G, C, D = pre.qg.shape
    S = k_cache.shape[2]
    b = pre.block_size
    nb = S // b
    BKV = B * Hkv
    if (k_scale is None) != (v_scale is None):
        raise ValueError(
            "k_scale and v_scale must be provided together (int8 cache), got "
            f"k_scale={'set' if k_scale is not None else None} "
            f"v_scale={'set' if v_scale is not None else None}")
    if q_pos.shape != (B, C):
        raise ValueError(
            f"q_pos shape {q_pos.shape} does not match the (B, C) = "
            f"({B}, {C}) of queries {pre.qg.shape}")

    c_tile = 1 if resolve_kernel_mode(mode, C) == "latency" \
        else min(C, THROUGHPUT_C_TILE)
    pad = (-C) % c_tile
    Cp = C + pad

    q4 = pre.qg.astype(jnp.float32).reshape(BKV, G, C, D)
    qpos4 = jnp.broadcast_to(
        q_pos[:, None, None, :], (B, Hkv, G, C)
    ).astype(jnp.int32).reshape(BKV, G, C)[..., None]
    if pad:  # ragged chunk boundary: padded rows select nothing, sliced off
        q4 = jnp.pad(q4, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qpos4 = jnp.pad(qpos4, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=-1)

    k4 = k_cache.reshape(BKV, nb, b, *k_cache.shape[3:])
    v4 = v_cache.reshape(BKV, nb, b, *v_cache.shape[3:])
    quant = k_scale is not None
    if quant:
        ks4 = k_scale.astype(jnp.float32).reshape(BKV, nb, b)[..., None]
        vs4 = v_scale.astype(jnp.float32).reshape(BKV, nb, b)[..., None]
    else:  # dummy tiles keep the arity static; never DMA'd (static skip)
        ks4 = jnp.zeros((1, 1, 1, 1), jnp.float32)
        vs4 = ks4
    kds3 = pre.k_ds.astype(jnp.float32).reshape(BKV, nb, D)
    vds3 = pre.v_ds.astype(jnp.float32).reshape(BKV, nb, D)
    counts2 = pre.counts.astype(jnp.float32)
    pb2 = pre.pb.astype(jnp.int32)
    with_upper = pre.upper is not None
    if with_upper:  # H-level hierarchy (§14): levels + tail as resident tiles
        nu = pre.upper.k_mean.shape[2]
        hk3 = pre.upper.k_mean.astype(jnp.float32).reshape(BKV, nu, D)
        hv3 = pre.upper.v_mean.astype(jnp.float32).reshape(BKV, nu, D)
        hcnt2 = pre.upper.counts.astype(jnp.float32)
    else:  # dummy tiles keep the arity static; the fold is compiled out
        hk3 = jnp.zeros((1, 1, D), jnp.float32)
        hv3 = hk3
        hcnt2 = jnp.zeros((1, 1), jnp.float32)

    out = _chunk_attention_call(
        q4, qpos4, kds3, vds3, counts2, pb2, hk3, hv3, hcnt2, k4, v4, ks4,
        vs4, pre.scale, b, m, c_tile, quant, include_bg, with_upper,
        interpret,
    )
    return out[:, :, :C].reshape(B, Hkv * G, C, D)
