"""Pallas TPU kernel: ring-paged chunk/decode MRA attention for serving.

This is the serving-side twin of the training kernels in
``block_sparse_attn.py`` (DESIGN.md §11). The pure-jnp serving hot path
(``core/mra_decode.py::mra2_chunk_attention``) materializes an
``(B, Hkv, G, C, m, b, D)`` gathered-page tensor and the matching exp-weight
tensors in HBM on every decode wave and verify chunk; this kernel keeps the
gather on-chip: the per-query *selected page ids* ride in SMEM via
``PrefetchScalarGridSpec`` and the BlockSpec ``index_map`` DMAs exactly the
selected K/V pages HBM→VMEM, one page per grid step.

Grid: ``(BQ, m)`` with ``BQ = B·Hkv·G·C`` flattened query rows (decode is the
C == 1 case) and ``m`` the selection budget. Output-tile revisits of a row
are consecutive, so the per-row accumulators (numerator tile, row sum,
running max) stay resident in VMEM between grid steps — the same
sequential-grid accumulation contract the training kernels rely on.

Fused per query row (matching the jnp path's math, DESIGN.md §11):

  * exact term — flash-style *online* softmax over the m selected pages:
    each page raises a running per-query max and rescales the resident
    numerator/row-sum by ``exp(m_old − m_new)``; masked exactly to
    ``pos_k <= q_pos`` inside the (possibly partial) pages.
  * int8 dequant — when the cache is quantized, the gathered page is
    dequantized *in kernel* from the per-token scales tile (the jnp path's
    gather-then-dequant, without the HBM round trip).
  * coarse background — at the last grid step the masked coarse score row
    (computed in jnp for the top-m selection anyway) is turned into the
    background term ``Σ_bg exp(μ − c)·count_y · v̄_y`` against the resident
    ``v_ds`` page-means tile, aligned onto the per-token stabilizer
    ``c_tok = max(c, fine_max)`` by ``exp(c − c_tok)`` — the two-level
    stabilizer of DESIGN.md §3, decode flavor.
  * the normalized output is emitted directly (all-masked rows → 0), so no
    unnormalized intermediates ever reach HBM.

Top-m page selection stays in jnp: the coarse scores are O(C·nb) and feed
``jax.lax.top_k``; what the kernel removes is the O(m·b·D) gather traffic
and the fused softmax/background/normalize passes over it.

Forward-only by design: the serving path is never differentiated (training
uses the §3 kernels). Differentiating through this op raises at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.mra import NEG_INF  # shared finite "minus infinity" sentinel


def _dot(a, b_, dims):
    return jax.lax.dot_general(a, b_, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _chunk_kernel(
    # scalar prefetch (SMEM)
    ysel_ref,   # (BQ, m) selected *physical* page ids (drive the DMA)
    blk_ref,    # (BQ, m) logical block of each selection (-1 dead)
    selok_ref,  # (BQ, m) 1 = selection valid (top_k hit a live allowed page)
    qpos_ref,   # (BQ, 1) global position of the query token
    # VMEM tiles
    q_ref,      # (1, D) query row
    k_ref,      # (1, 1, b, D) selected K page
    v_ref,      # (1, 1, b, D) selected V page
    ks_ref,     # (1, 1, b) K dequant scales ((1,1,b) dummy when not quant)
    vs_ref,     # (1, 1, b) V dequant scales
    coarse_ref,  # (1, nb) masked coarse scores (NEG_INF off-support)
    counts_ref,  # (1, nb) valid tokens per page
    pb_ref,     # (1, nb) page table row (logical block per page, -1 dead)
    vds_ref,    # (1, nb, D) per-page V means (coarse background values)
    # outputs (accumulators resident across the m grid steps of a row)
    o_ref,      # (1, D) numerator, normalized in place at the last step
    rs_ref,     # (1, 1) row sum
    mt_ref,     # (1, 1) running fine-score max
    *,
    scale: float,
    block_size: int,
    m: int,
    quant: bool,
    include_bg: bool,
):
    r = pl.program_id(0)
    i = pl.program_id(1)
    b = block_size

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        rs_ref[...] = jnp.zeros_like(rs_ref)
        mt_ref[...] = jnp.zeros_like(mt_ref) + NEG_INF

    q = q_ref[...].astype(jnp.float32)      # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)     # (b, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:  # int8 pages: dequantize in VMEM from the per-token scales
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]

    s = _dot(q, k, ((1,), (1,))) * scale    # (1, b)
    qpos = qpos_ref[r, 0]
    blk = blk_ref[r, i]
    pos = blk * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    ok = (selok_ref[r, i] == 1) & (blk >= 0) & (pos <= qpos)

    # online two-level stabilization (flash-style): raise the running max,
    # shrink the resident accumulators, add this page at the new max.
    m_old = mt_ref[0, 0]
    m_new = jnp.maximum(m_old, jnp.max(jnp.where(ok, s, NEG_INF)))
    alpha = jnp.exp(m_old - m_new)  # ≤ 1; underflows to 0 from the NEG_INF init
    a = jnp.where(ok, jnp.exp(jnp.minimum(s - m_new, 0.0)), 0.0)
    o_ref[...] = o_ref[...] * alpha + _dot(a, v, ((1,), (0,)))
    rs_ref[...] = rs_ref[...] * alpha + jnp.sum(a)
    mt_ref[...] = jnp.zeros_like(mt_ref) + m_new

    @pl.when(i == m - 1)
    def _finalize():
        coarse = coarse_ref[...]            # (1, nb), NEG_INF off-support
        c = jnp.maximum(jnp.max(coarse), NEG_INF * 0.5)
        mt = mt_ref[0, 0]
        c_tok = jnp.maximum(c, mt)          # two-level per-token stabilizer
        fine_adj = jnp.exp(mt - c_tok)      # mt ≤ c_tok, so ≤ 1
        out = o_ref[...] * fine_adj
        rs = rs_ref[0, 0] * fine_adj
        if include_bg:  # MRA-2 "full": coarse pyramid background
            cnt = counts_ref[...]           # (1, nb)
            pb = pb_ref[...]                # (1, nb)
            jq = qpos_ref[r, 0] // b
            # background support: live past pages minus the query's own block
            # minus the exactly-evaluated selections (jnp's bg mask).
            bg = (cnt > 0.0) & (pb <= jq) & (pb != jq)
            col = jax.lax.broadcasted_iota(jnp.int32, (1, coarse.shape[1]), 1)
            for j in range(m):  # static unroll: m is small, SMEM reads scalar
                bg = bg & ~((selok_ref[r, j] == 1) & (col == ysel_ref[r, j]))
            # coarse ≤ c on the support by construction, so exp arg ≤ 0
            w = jnp.where(bg, jnp.exp(coarse - c), 0.0) * cnt
            adj = jnp.exp(c - c_tok)
            vds = vds_ref[0].astype(jnp.float32)  # (nb, D)
            out = out + adj * _dot(w, vds, ((1,), (0,)))
            rs = rs + adj * jnp.sum(w)
        alive = rs > 0.0
        o_ref[...] = jnp.where(alive, out, 0.0) / jnp.where(alive, rs, 1.0)


def _no_grad(*args, **kw):
    raise NotImplementedError(
        "mra2 chunk/decode kernel is forward-only (serving path); training "
        "differentiates through the §3 block-sparse kernels instead")


@functools.partial(
    jax.custom_jvp, nondiff_argnums=(12, 13, 14, 15, 16, 17))
def _chunk_attention_call(
    q2, k4, v4, ks3, vs3, coarse2, counts2, pb2, vds3,
    ysel, blk, qselok,
    scale, block_size, m, quant, include_bg, interpret,
):
    """pallas_call entry. q2 (BQ, D); k4/v4 (BKV, nb, b, D); coarse2 (BQ, nb);
    counts2/pb2 (B, nb); vds3 (BKV, nb, D); ysel/blk (BQ, m) int32;
    qselok (BQ, m + 1) int32 = [q_pos | selok] packed (q_pos column first)."""
    BQ, D = q2.shape
    BKV, nb, b, _ = k4.shape
    B = counts2.shape[0]
    gc = BQ // BKV       # G * C: query rows per KV row
    hgc = BQ // B        # Hkv * G * C: query rows per batch row
    qpos = qselok[:, :1]
    selok = qselok[:, 1:]

    kernel = functools.partial(
        _chunk_kernel, scale=scale, block_size=b, m=m, quant=quant,
        include_bg=include_bg)
    # ``quant`` is static: without scales the (1, 1, b) dummy tiles map to a
    # constant block index, so they are DMA'd once and never re-fetched (the
    # kernel body also statically skips them).
    if quant:
        scale_map = lambda r, i, ys, bl, so, qp: (r // gc, ys[r, i], 0)  # noqa: E731
    else:
        scale_map = lambda r, i, ys, bl, so, qp: (0, 0, 0)  # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(BQ, m),
        in_specs=[
            pl.BlockSpec((1, D), lambda r, i, ys, bl, so, qp: (r, 0)),
            pl.BlockSpec((1, 1, b, D),
                         lambda r, i, ys, bl, so, qp: (r // gc, ys[r, i], 0, 0)),
            pl.BlockSpec((1, 1, b, D),
                         lambda r, i, ys, bl, so, qp: (r // gc, ys[r, i], 0, 0)),
            pl.BlockSpec((1, 1, b), scale_map),
            pl.BlockSpec((1, 1, b), scale_map),
            pl.BlockSpec((1, nb), lambda r, i, ys, bl, so, qp: (r, 0)),
            pl.BlockSpec((1, nb), lambda r, i, ys, bl, so, qp: (r // hgc, 0)),
            pl.BlockSpec((1, nb), lambda r, i, ys, bl, so, qp: (r // hgc, 0)),
            pl.BlockSpec((1, nb, D),
                         lambda r, i, ys, bl, so, qp: (r // gc, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda r, i, ys, bl, so, qp: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, i, ys, bl, so, qp: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, i, ys, bl, so, qp: (r, 0)),
        ],
    )
    out, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((BQ, D), jnp.float32),
            jax.ShapeDtypeStruct((BQ, 1), jnp.float32),
            jax.ShapeDtypeStruct((BQ, 1), jnp.float32),
        ),
        interpret=interpret,
    )(ysel, blk, selok, qpos, q2, k4, v4, ks3, vs3, coarse2, counts2, pb2,
      vds3)
    return out


_chunk_attention_call.defjvp(_no_grad)


def chunk_attention_kernel(
    pre,
    k_cache: jax.Array,
    v_cache: jax.Array,
    q_pos: jax.Array,
    *,
    k_scale=None,
    v_scale=None,
    include_bg: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Fused chunk/decode attention from a selection prelude.

    ``pre`` is ``core.mra_decode.ChunkPrelude`` (coarse scores, top-m page
    selection, page stats) — the jnp half shared bit-for-bit with the pure
    path. Returns (B, Hq, C, D) fp32; the caller casts to q.dtype.
    """
    B, Hkv, G, C, D = pre.qg.shape
    S = k_cache.shape[2]
    b = pre.block_size
    nb = S // b
    m = pre.y_idx.shape[-1]
    BQ = B * Hkv * G * C
    BKV = B * Hkv

    q2 = pre.qg.astype(jnp.float32).reshape(BQ, D)
    k4 = k_cache.reshape(BKV, nb, b, *k_cache.shape[3:])
    v4 = v_cache.reshape(BKV, nb, b, *v_cache.shape[3:])
    quant = k_scale is not None
    if quant:
        ks3 = k_scale.astype(jnp.float32).reshape(BKV, nb, b)
        vs3 = v_scale.astype(jnp.float32).reshape(BKV, nb, b)
    else:  # one dummy tile keeps the arity static; constant index_map, no
        # per-step DMA, and the kernel body statically skips it
        ks3 = jnp.zeros((1, 1, b), jnp.float32)
        vs3 = ks3
    coarse2 = pre.coarse_m.astype(jnp.float32).reshape(BQ, nb)
    counts2 = pre.counts.astype(jnp.float32)
    pb2 = pre.pb.astype(jnp.int32)
    vds3 = pre.v_ds.astype(jnp.float32).reshape(BKV, nb, D)

    ysel = pre.y_idx.astype(jnp.int32).reshape(BQ, m)
    # logical block of each selected physical page (positions mask)
    blk = jnp.take_along_axis(
        jnp.broadcast_to(pre.pb[:, None, None, None, :], (B, Hkv, G, C, nb)),
        pre.y_idx, axis=-1).astype(jnp.int32).reshape(BQ, m)
    selok = pre.sel_ok.astype(jnp.int32).reshape(BQ, m)
    qpos = jnp.broadcast_to(
        q_pos[:, None, None, :], (B, Hkv, G, C)).astype(jnp.int32)
    qselok = jnp.concatenate([qpos.reshape(BQ, 1), selok], axis=1)

    out = _chunk_attention_call(
        q2, k4, v4, ks3, vs3, coarse2, counts2, pb2, vds3,
        ysel, blk, qselok,
        pre.scale, b, m, quant, include_bg, interpret,
    )
    return out.reshape(B, Hkv * G, C, D)
