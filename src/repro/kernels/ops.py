"""Jit'd public wrapper for the block-sparse attention kernel.

``block_sparse_attention`` sorts the selected block pairs by query block
(making output-tile revisits consecutive, see block_sparse_attn.py), derives
the first-visit flags, dispatches to the Pallas kernel, and provides a
custom VJP whose backward pass is the flash-style recompute in pure jnp
(no activation of size O(m·b²) is saved).

Contract: every query block id in [0, nb) must appear in ``x_idx`` at least
once per row — guaranteed by MraConfig.force_diagonal (the default); the
kernel leaves unvisited output tiles uninitialized otherwise.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse_attn import block_sparse_attention_fwd
from .ref import block_sparse_attention_ref


def _float0(x):
    return np.zeros(x.shape, jax.dtypes.float0)


def _prepare(x_idx, y_idx, flags):
    order = jnp.argsort(x_idx, axis=-1, stable=True)
    xs = jnp.take_along_axis(x_idx, order, axis=-1)
    ys = jnp.take_along_axis(y_idx, order, axis=-1)
    fl = jnp.take_along_axis(flags, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(xs[:, :1]), (xs[:, 1:] != xs[:, :-1]).astype(xs.dtype)], axis=-1
    )
    return xs, ys, fl, first


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def block_sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    c: jax.Array,
    x_idx: jax.Array,
    y_idx: jax.Array,
    flags: jax.Array,
    scale: float = 1.0,
    block_size: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Unnormalized block-sparse attention numerator + row sums.

    Args:
      q: (BHG, n, d); k/v: (BHKV, n, d) with BHG % BHKV == 0 (GQA groups).
      c: (BHG, nb) fp32 per-query-block softmax stabilizer.
      x_idx/y_idx: (BHG, m) int32 selected (query-block, key-block) pairs.
      flags: (BHG, m) int32 — bit0: pair is valid; bit1: apply causal
        triangular mask inside the block (diagonal blocks).
      scale: softmax scale (static).
      block_size: b (static).
      interpret: run the Pallas kernel in interpret mode (CPU validation).

    Returns:
      out (BHG, n, d) fp32, rowsum (BHG, n) fp32.
    """
    xs, ys, fl, first = _prepare(x_idx, y_idx, flags)
    return block_sparse_attention_fwd(
        q, k, v, xs.astype(jnp.int32), ys.astype(jnp.int32),
        first.astype(jnp.int32), fl.astype(jnp.int32), c,
        scale=scale, block_size=block_size, interpret=interpret,
    )


def _fwd(q, k, v, c, x_idx, y_idx, flags, scale, block_size, interpret):
    out = block_sparse_attention(
        q, k, v, c, x_idx, y_idx, flags, scale, block_size, interpret
    )
    return out, (q, k, v, c, x_idx, y_idx, flags)


def _bwd(scale, block_size, interpret, res, cts):
    q, k, v, c, x_idx, y_idx, flags = res
    do, dr = cts
    BHG, n, d = q.shape
    BHKV = k.shape[0]
    G = BHG // BHKV
    b = block_size
    nb = n // b

    from .ref import _gather_blocks

    kx = jnp.broadcast_to(k[:, None], (BHKV, G, n, d)).reshape(BHG, n, d)
    vx = jnp.broadcast_to(v[:, None], (BHKV, G, n, d)).reshape(BHG, n, d)
    q_blk = _gather_blocks(q.astype(jnp.float32), x_idx, b)
    k_blk = _gather_blocks(kx.astype(jnp.float32), y_idx, b)
    v_blk = _gather_blocks(vx.astype(jnp.float32), y_idx, b)
    c_sel = jnp.take_along_axis(c, x_idx, axis=1)

    s = jnp.einsum("rmid,rmjd->rmij", q_blk, k_blk) * scale - c_sel[..., None, None]
    valid = (flags & 1) == 1
    diag = (flags & 2) == 2
    tri = jnp.arange(b)[:, None] >= jnp.arange(b)[None, :]
    mask = jnp.where(diag[..., None, None], tri[None, None], True)
    mask = jnp.logical_and(mask, valid[..., None, None])
    a = jnp.where(mask, jnp.exp(jnp.minimum(s, 80.0)), 0.0)

    do_blk = _gather_blocks(do.astype(jnp.float32), x_idx, b)
    dr_blk = jnp.take_along_axis(
        dr.reshape(BHG, nb, b).astype(jnp.float32), x_idx[..., None], axis=1
    )
    da = jnp.einsum("rmid,rmjd->rmij", do_blk, v_blk) + dr_blk[..., None]
    ds = a * da

    dq_blk = jnp.einsum("rmij,rmjd->rmid", ds, k_blk) * scale
    dk_blk = jnp.einsum("rmij,rmid->rmjd", ds, q_blk) * scale
    dv_blk = jnp.einsum("rmij,rmid->rmjd", a, do_blk)
    dc_blk = -jnp.sum(ds, axis=(-1, -2))  # (BHG, m)

    seg = jax.vmap(lambda z, i, u: z.at[i].add(u))
    dq = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), x_idx, dq_blk).reshape(BHG, n, d)
    dkx = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), y_idx, dk_blk)
    dvx = seg(jnp.zeros((BHG, nb, b, d), jnp.float32), y_idx, dv_blk)
    dk = jnp.sum(dkx.reshape(BHKV, G, nb, b, d), axis=1).reshape(BHKV, n, d)
    dv = jnp.sum(dvx.reshape(BHKV, G, nb, b, d), axis=1).reshape(BHKV, n, d)
    dc = seg(jnp.zeros((BHG, nb), jnp.float32), x_idx, dc_blk)

    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        dc.astype(c.dtype),
        _float0(x_idx),
        _float0(y_idx),
        _float0(flags),
    )


block_sparse_attention.defvjp(_fwd, _bwd)
