"""Jit'd public wrapper for the block-sparse attention kernels (fwd + bwd).

``block_sparse_attention`` sorts the selected block pairs by query block
(making output-tile revisits consecutive, see block_sparse_attn.py), derives
the first-visit flags, dispatches to the Pallas forward kernel, and provides
a custom VJP. The backward pass is a flash-style recompute (no activation of
size O(m·b²) is saved; only the (BHG, n) per-token stabilizer ``mt`` rides
along as a residual) with two implementations selected by the static
``bwd_impl`` argument:

  * ``"pallas"`` (default): the fused Pallas backward kernels — one pass
    sorted by query block (dq), one pass flattened per KV head and sorted
    by key block (dk, dv with the GQA group reduction fused in).
  * ``"jnp"``: the pure-jnp gather/recompute oracle (kernels/ref.py), used
    as the CPU fallback and as the differential-testing baseline.

The stabilizer is gradient-transparent by contract: cotangents of the
``mt`` output are ignored and dc ≡ 0 (stabilizers cancel in the caller's
normalized output; the pure-jnp MRA path stop-gradients its per-token
stabilizer the same way).

Contract: every query block id in [0, nb) must appear in ``x_idx`` at least
once per row — guaranteed by MraConfig.force_diagonal (the default); the
forward kernel leaves unvisited output tiles uninitialized otherwise. The
backward needs the same coverage for *key* blocks; ``_bwd`` guarantees both
by appending one invalid (zero-contribution) pair per block id before
sorting, so it holds for arbitrary index sets.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse_attn import block_sparse_attention_bwd, block_sparse_attention_fwd
from .ref import block_sparse_attention_bwd_ref


def _float0(x):
    return np.zeros(x.shape, jax.dtypes.float0)


def _prepare(x_idx, y_idx, flags):
    """Sort pairs by query block; derive first-visit flags."""
    order = jnp.argsort(x_idx, axis=-1, stable=True)
    xs = jnp.take_along_axis(x_idx, order, axis=-1)
    ys = jnp.take_along_axis(y_idx, order, axis=-1)
    fl = jnp.take_along_axis(flags, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(xs[:, :1]), (xs[:, 1:] != xs[:, :-1]).astype(xs.dtype)], axis=-1
    )
    return xs, ys, fl, first


def _prepare_kv(x_idx, y_idx, flags, G):
    """Flatten each KV head's G groups of pairs into one list sorted by key
    block. Returns (BHKV, G·m) arrays: owning BHG row, x, y, first, flags."""
    BHG, m = x_idx.shape
    BHKV = BHG // G
    M2 = G * m
    rows = jnp.broadcast_to(
        jnp.arange(BHG, dtype=jnp.int32)[:, None], (BHG, m)
    ).reshape(BHKV, M2)
    x2 = x_idx.reshape(BHKV, M2)
    y2 = y_idx.reshape(BHKV, M2)
    f2 = flags.reshape(BHKV, M2)
    order = jnp.argsort(y2, axis=-1, stable=True)
    rows = jnp.take_along_axis(rows, order, axis=-1)
    x2 = jnp.take_along_axis(x2, order, axis=-1)
    y2 = jnp.take_along_axis(y2, order, axis=-1)
    f2 = jnp.take_along_axis(f2, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(y2[:, :1]), (y2[:, 1:] != y2[:, :-1]).astype(y2.dtype)], axis=-1
    )
    return rows, x2, y2, first, f2


def _coverage_pad(x_idx, y_idx, flags, nb):
    """Append one invalid pair per block id (x = y = j, flags = 0).

    Invalid pairs contribute nothing (mask bit0 unset → a ≡ 0 → zero
    gradients) but guarantee every dq *and* dk/dv output tile is visited,
    and therefore zero-initialized, for arbitrary index sets.
    """
    BHG = x_idx.shape[0]
    pad = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[None], (BHG, nb))
    zeros = jnp.zeros((BHG, nb), jnp.int32)
    return (
        jnp.concatenate([x_idx, pad], axis=1),
        jnp.concatenate([y_idx, pad], axis=1),
        jnp.concatenate([flags, zeros], axis=1),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11))
def _block_sparse_attention(
    q, k, v, c, x_idx, y_idx, flags, km, scale, block_size, interpret, bwd_impl
):
    xs, ys, fl, first = _prepare(x_idx, y_idx, flags)
    return block_sparse_attention_fwd(
        q, k, v, xs.astype(jnp.int32), ys.astype(jnp.int32),
        first.astype(jnp.int32), fl.astype(jnp.int32), c,
        km.astype(jnp.float32),
        scale=scale, block_size=block_size, interpret=interpret,
    )


def _fwd(q, k, v, c, x_idx, y_idx, flags, km, scale, block_size, interpret,
         bwd_impl):
    out, rowsum, mt = _block_sparse_attention(
        q, k, v, c, x_idx, y_idx, flags, km, scale, block_size, interpret,
        bwd_impl
    )
    return (out, rowsum, mt), (q, k, v, c, mt, x_idx, y_idx, flags, km)


def _bwd(scale, block_size, interpret, bwd_impl, res, cts):
    q, k, v, c, mt, x_idx, y_idx, flags, km = res
    do, dr, _ = cts  # mt is gradient-transparent: its cotangent is dropped
    b = block_size
    nb = q.shape[1] // b
    G = q.shape[0] // k.shape[0]

    if bwd_impl == "pallas":
        xi = x_idx.astype(jnp.int32)
        yi = y_idx.astype(jnp.int32)
        fl = flags.astype(jnp.int32)
        xi, yi, fl = _coverage_pad(xi, yi, fl, nb)
        xq, yq, flq, firstq = _prepare(xi, yi, fl)
        rowk, xk, yk, firstk, flk = _prepare_kv(xi, yi, fl, G)
        dq, dk, dv = block_sparse_attention_bwd(
            q, k, v, mt,
            do.astype(jnp.float32), dr.astype(jnp.float32),
            km.astype(jnp.float32),
            xq, yq, firstq, flq,
            rowk, xk, yk, firstk, flk,
            scale=scale, block_size=b, interpret=interpret,
        )
    else:
        dq, dk, dv = block_sparse_attention_bwd_ref(
            q, k, v, c, x_idx, y_idx, flags, km, do, dr,
            scale=scale, block_size=b,
        )

    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        jnp.zeros_like(c),  # dc ≡ 0: the stabilizer is gradient-transparent
        _float0(x_idx),
        _float0(y_idx),
        _float0(flags),
        _float0(km),
    )


_block_sparse_attention.defvjp(_fwd, _bwd)


def block_sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    c: jax.Array,
    x_idx: jax.Array,
    y_idx: jax.Array,
    flags: jax.Array,
    key_mask: Optional[jax.Array] = None,
    *,
    scale: float = 1.0,
    block_size: int = 32,
    interpret: bool = False,
    bwd_impl: str = "pallas",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized block-sparse attention numerator, row sums, stabilizer.

    Args:
      q: (BHG, n, d); k/v: (BHKV, n, d) with BHG % BHKV == 0 (GQA groups).
      c: (BHG, nb) fp32 stabilizer *floor* per query block (the MRA-2 coarse
        background max, clamped above NEG_INF/2). The kernel raises it to
        the exact per-token score max online (flash-style), so weights never
        overflow; dc ≡ 0 by contract.
      x_idx/y_idx: (BHG, m) int32 selected (query-block, key-block) pairs.
      flags: (BHG, m) int32 — bit0: pair is valid; bit1: apply causal
        triangular mask inside the block (diagonal blocks).
      key_mask: optional (BHKV, n) key validity (>0 = valid); padded keys
        are excluded from scores, row sums, and gradients.
      scale: softmax scale (static).
      block_size: b (static).
      interpret: run the Pallas kernels in interpret mode (CPU validation).
      bwd_impl: "pallas" (fused backward kernels) or "jnp" (ref fallback).

    Returns:
      out (BHG, n, d) fp32, rowsum (BHG, n) fp32, mt (BHG, n) fp32 — the
      numerator/row sums are stabilized by exp(−mt); mt is stop-gradient.
    """
    if bwd_impl not in ("pallas", "jnp"):
        raise ValueError(f"bwd_impl must be 'pallas' or 'jnp', got {bwd_impl!r}")
    if key_mask is None:
        key_mask = jnp.ones((k.shape[0], k.shape[1]), jnp.int32)
    return _block_sparse_attention(
        q, k, v, c, x_idx.astype(jnp.int32), y_idx.astype(jnp.int32),
        flags.astype(jnp.int32), key_mask.astype(jnp.int32),
        scale, block_size, interpret, bwd_impl,
    )
