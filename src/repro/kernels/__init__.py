"""Pallas TPU kernels for the paper's perf-critical block-sparsity operators.

block_sparse_attn.py — pl.pallas_call + PrefetchScalarGridSpec kernel for the
MRA-2 high-resolution term (data-dependent block gathers via SMEM indices,
sequential-grid accumulation, fp32 MXU accumulation).
ops.py  — jit'd public wrapper (sorting, first-visit flags, custom VJP whose
backward is a flash-style jnp recompute).
ref.py  — pure-jnp oracle used by the interpret-mode kernel tests.
"""
from .ops import block_sparse_attention
from .ref import block_sparse_attention_ref
