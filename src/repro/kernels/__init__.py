"""Pallas TPU kernels for the paper's perf-critical block-sparsity operators.

block_sparse_attn.py — pl.pallas_call + PrefetchScalarGridSpec kernels for
the MRA-2 high-resolution term, forward AND backward (data-dependent block
gathers via SMEM indices, sequential-grid accumulation, flash-style online
softmax stabilization, fp32 MXU accumulation). Key-padding masks and causal
flags ride along, so the kernels serve training and arbitrary-length
traffic (DESIGN.md §3).
ops.py  — jit'd public wrapper (sorting, first-visit flags, coverage
padding, custom VJP dispatching to the fused Pallas backward with a jnp
fallback).
ref.py  — pure-jnp fwd/bwd oracle shared by the interpret-mode kernel
tests, the differential harness, and the custom-VJP jnp fallback.
"""
from .ops import block_sparse_attention
from .ref import block_sparse_attention_bwd_ref, block_sparse_attention_ref
