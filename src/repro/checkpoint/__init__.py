from .ckpt import AsyncCheckpointer, latest_step, restore, save
