"""Sharded checkpointing: per-leaf .npy + JSON manifest, atomic, async-able.

Fault-tolerance contract (DESIGN.md §2):
  * atomic: data is written to ``<dir>/step_N.tmp`` and renamed to
    ``<dir>/step_N`` only after the manifest fsync — a crash mid-write never
    corrupts the latest checkpoint;
  * restartable: ``latest_step``/``restore`` pick up the newest complete
    checkpoint; data pipeline state is just the step counter (deterministic
    streams), so restarts are bit-identical;
  * elastic: ``restore`` returns host arrays which the caller ``device_put``s
    with *its own* shardings — restoring onto a different mesh shape or
    device count re-shards transparently (elastic scaling);
  * async: ``AsyncCheckpointer`` snapshots to host then writes in a
    background thread, overlapping I/O with the next training steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["leaf_" + "".join(jax.tree_util.keystr(p)).replace("/", "_") for p, _ in flat]
    # sanitize
    names = ["".join(ch if ch.isalnum() or ch in "._-" else "_" for ch in n) for n in names]
    return names, [v for _, v in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, shardings: Any = None):
    """Restore a tree saved with ``save``. ``like`` supplies the tree structure.

    When ``shardings`` (a matching tree of Shardings) is given, leaves are
    device_put with them — this is the elastic re-shard path.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    names, _, treedef = _flatten_with_names(like)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = {m["name"]: m for m in json.load(f)["leaves"]}

    def _load(n):
        arr = np.load(os.path.join(path, n + ".npy"))
        want = manifest[n]["dtype"]
        if str(arr.dtype) != want:  # ml_dtypes (bfloat16, ...) load as raw void
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        return arr

    leaves = [_load(n) for n in names]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, ckpt_dir: str, step: int, tree: Any, *, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            self.last_path = save(ckpt_dir, step, host_tree, extra=extra)

        self._thread = threading.Thread(target=_write, daemon=False)
        self._thread.start()
