"""Paper Fig. 5: approximation error vs. softmax entropy.

Sweeping the score temperature moves the attention entropy; the paper shows
MRA-2 stays accurate across the whole range while low-rank methods fail at
low entropy and window-sparsity at high entropy.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.attention import AttentionSpec, self_attention
from repro.core.mra import MraConfig, mra2_attention

from .common import rel_error, structured_qkv


def _entropy(q, k):
    D = q.shape[-1]
    s = jnp.einsum("bhid,bhjd->bhij", q, k) / (D**0.5)
    p = jnp.asarray(jnp.exp(s - jnp.max(s, -1, keepdims=True)))
    p = p / p.sum(-1, keepdims=True)
    h = -(p * jnp.log(p + 1e-12)).sum(-1)
    return float(h.mean())


def run(emit):
    rng = np.random.default_rng(1)
    base_q, base_k, v = structured_qkv(rng, B=1, H=4, N=512, D=64)
    for temp in (0.25, 0.5, 1.0, 2.0, 4.0):
        q = base_q * np.sqrt(temp)
        k = base_k * np.sqrt(temp)
        h = _entropy(q, k)
        cfg = MraConfig(block_size=32, blocks_per_row=4)
        err_mra = rel_error(mra2_attention(q, k, v, cfg), q, k, v)
        emit(f"entropy{h:.2f}_mra2", 0.0, f"{err_mra:.4f}")
        for kind in ("linformer", "performer", "longformer"):
            spec = AttentionSpec(kind=kind)
            err = rel_error(self_attention(q, k, v, spec), q, k, v)
            emit(f"entropy{h:.2f}_{kind}", 0.0, f"{err:.4f}")
