"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric:
relative error, NLL, scaling exponent, or a boolean claim check), and can
mirror them to a JSON file (``--json``) for the CI perf-trajectory artifact.

  approx_error  -> paper Fig. 1 + Fig. 4 / Tab. 7 (error vs budget/method)
  entropy_error -> paper Fig. 5 (error vs softmax entropy)
  scaling       -> paper Tab. 7 (runtime scaling 256..4096)
  swap_eval     -> paper Tab. 1/2 (drop-in compatibility with trained weights)
  decode_bench  -> beyond-paper MRA decode (KV-block selection)
  kernel_bench  -> fwd+bwd Pallas-kernel vs jnp path timing + grad parity
  serve_bench   -> continuous-batching engine (req/s, tok/s, inter-token
                   latency p50/p99, chunked-prefill dispatch economy)

``--mesh DxM`` (default "1": no mesh) activates a (data, model) device mesh
for the run: modules read it via ``mesh_utils.get_mesh()`` and place/shard
their inputs accordingly (decode_bench drives the shard_map TP decode path).
Use ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to validate
sharded runs on a CPU host.
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module subset")
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    ap.add_argument("--json", default=None,
                    help="also write results to this JSON file (CI artifact)")
    args = ap.parse_args()

    from repro.distributed import mesh_utils
    from repro.launch.mesh import parse_mesh

    from . import (approx_error, decode_bench, entropy_error, kernel_bench,
                   scaling, serve_bench, swap_eval)

    modules = {
        "approx_error": approx_error,
        "entropy_error": entropy_error,
        "scaling": scaling,
        "swap_eval": swap_eval,
        "decode_bench": decode_bench,
        "kernel_bench": kernel_bench,
        "serve_bench": serve_bench,
    }
    chosen = args.only.split(",") if args.only else list(modules)
    mesh = parse_mesh(args.mesh)

    print("name,us_per_call,derived")
    rows = []

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows.append({"name": name, "us_per_call": us, "derived": str(derived)})

    with mesh_utils.use_mesh(mesh):
        for name in chosen:
            modules[name].run(emit)

    if args.json:
        meta = {"mesh": args.mesh, "modules": chosen}
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=2)
        print(f"[bench] wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
