"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric:
relative error, NLL, scaling exponent, or a boolean claim check), and can
mirror them to a JSON file (``--json``) for the CI perf-trajectory artifact.

  approx_error  -> paper Fig. 1 + Fig. 4 / Tab. 7 (error vs budget/method)
  entropy_error -> paper Fig. 5 (error vs softmax entropy)
  scaling       -> paper Tab. 7 (runtime scaling 256..4096)
  swap_eval     -> paper Tab. 1/2 (drop-in compatibility with trained weights)
  decode_bench  -> beyond-paper MRA decode (KV-block selection)
  kernel_bench  -> fwd+bwd Pallas-kernel vs jnp path timing + grad parity
  serve_bench   -> continuous-batching engine (req/s, tok/s, inter-token
                   latency p50/p99, chunked-prefill dispatch economy)
  spec_bench    -> resolution-speculative decoding (acceptance rate vs K,
                   accepted-tokens-per-dispatch, tok/s vs PR 3 baseline)

``--list`` prints the registered benchmark names (one per line) and exits,
so CI scripts enumerate instead of hard-coding.

``--mesh DxM`` (default "1": no mesh) activates a (data, model) device mesh
for the run: modules read it via ``mesh_utils.get_mesh()`` and place/shard
their inputs accordingly (decode_bench drives the shard_map TP decode path).
Use ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to validate
sharded runs on a CPU host.
"""
import argparse
import json
import sys

# registry: name -> module basename under benchmarks/ (kept import-free so
# ``--list`` answers without pulling in jax)
MODULES = (
    "approx_error",
    "entropy_error",
    "scaling",
    "swap_eval",
    "decode_bench",
    "kernel_bench",
    "serve_bench",
    "spec_bench",
)

# row-presence schema: beyond the per-suite "emitted anything at all" check,
# these named rows are load-bearing for the BENCH_*.json trajectory (the
# telemetry acceptance rows, DESIGN.md §13) — a refactor that silently stops
# emitting one must fail the run, not ship a quietly thinner artifact
REQUIRED_ROWS = {
    "serve_bench": (
        "serve_ttft_p50",
        "serve_ttft_p99",
        "serve_telemetry_overhead_ratio",
        "serve_cache_occupancy",
        "serve_spec_accept_per_slot",
        "serve_longctx_tok_per_s",
    ),
    "spec_bench": ("spec_base_tok_per_dispatch",),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module subset")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    ap.add_argument("--json", default=None,
                    help="also write results to this JSON file (CI artifact)")
    ap.add_argument("--trace", default=None,
                    help="request-lifecycle trace JSONL output path, passed "
                         "to suites that accept trace_path (serve_bench)")
    args = ap.parse_args()

    if args.list:
        print("\n".join(MODULES))
        return

    import importlib

    from repro.distributed import mesh_utils
    from repro.launch.mesh import parse_mesh

    chosen = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in chosen if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; --list shows the registry")
    # import only what runs: each module pulls in jax + model code
    modules = {name: importlib.import_module(f"benchmarks.{name}")
               for name in chosen}
    mesh = parse_mesh(args.mesh)

    print("name,us_per_call,derived")
    rows = []

    def make_emit(suite):
        def emit(name, us, derived):
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            rows.append({"name": name, "us_per_call": us,
                         "derived": str(derived), "suite": suite})
        return emit

    import inspect

    with mesh_utils.use_mesh(mesh):
        for name in chosen:
            kwargs = {}
            if (args.trace
                    and "trace_path" in
                    inspect.signature(modules[name].run).parameters):
                kwargs["trace_path"] = args.trace
            modules[name].run(make_emit(name), **kwargs)

    # schema check: every chosen suite must have emitted at least one row.
    # A partial artifact (a module silently contributing nothing — e.g. an
    # import-time skip or an exception swallowed upstream) must fail loudly
    # here rather than be committed as the perf-trajectory baseline.
    empty = [n for n in chosen if not any(r["suite"] == n for r in rows)]
    if empty:
        sys.exit(f"[bench] FATAL: suites emitted zero rows: {empty} — "
                 "refusing to produce a partial artifact")
    names = {r["name"] for r in rows}
    missing = [f"{suite}:{row}" for suite in chosen
               for row in REQUIRED_ROWS.get(suite, ())
               if row not in names]
    if missing:
        sys.exit(f"[bench] FATAL: required rows missing: {missing} — "
                 "refusing to produce a partial artifact")

    if args.json:
        meta = {"mesh": args.mesh, "modules": chosen}
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=2)
        print(f"[bench] wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
