"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric:
relative error, NLL, scaling exponent, or a boolean claim check).

  approx_error  -> paper Fig. 1 + Fig. 4 / Tab. 7 (error vs budget/method)
  entropy_error -> paper Fig. 5 (error vs softmax entropy)
  scaling       -> paper Tab. 7 (runtime scaling 256..4096)
  swap_eval     -> paper Tab. 1/2 (drop-in compatibility with trained weights)
  decode_bench  -> beyond-paper MRA decode (KV-block selection)
  kernel_bench  -> fwd+bwd Pallas-kernel vs jnp path timing + grad parity
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module subset")
    args = ap.parse_args()

    from . import (approx_error, decode_bench, entropy_error, kernel_bench,
                   scaling, swap_eval)

    modules = {
        "approx_error": approx_error,
        "entropy_error": entropy_error,
        "scaling": scaling,
        "swap_eval": swap_eval,
        "decode_bench": decode_bench,
        "kernel_bench": kernel_bench,
    }
    chosen = args.only.split(",") if args.only else list(modules)

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    for name in chosen:
        modules[name].run(emit)


if __name__ == "__main__":
    main()
