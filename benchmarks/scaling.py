"""Paper Tab. 7: runtime/error scaling with sequence length (256..4096).

Wall-times are CPU-host measurements (relative scaling is the signal; the
absolute TPU numbers come from the roofline analysis). Confirms the paper's
complexity claim: MRA-2 cost grows ~linearly in n at fixed blocks_per_row
while exact attention grows quadratically.
"""
from __future__ import annotations

import numpy as np

from repro.core.mra import MraConfig, full_attention, mra2_attention

from .common import rel_error, structured_qkv, time_call


def run(emit):
    rng = np.random.default_rng(2)
    times_mra, times_full, lens = [], [], []
    for N in (256, 512, 1024, 2048, 4096):
        q, k, v = structured_qkv(rng, B=1, H=4, N=N, D=64)
        cfg = MraConfig(block_size=32, blocks_per_row=4)
        us = time_call(lambda q, k, v: mra2_attention(q, k, v, cfg), q, k, v)
        err = rel_error(mra2_attention(q, k, v, cfg), q, k, v)
        emit(f"mra2_n{N}", us, f"{err:.4f}")
        times_mra.append(us)
        lens.append(N)
        if N <= 2048:
            us_f = time_call(lambda q, k, v: full_attention(q, k, v), q, k, v)
            emit(f"full_n{N}", us_f, "0.0000")
            times_full.append(us_f)

    # empirical scaling exponents (log-log slope)
    def slope(ts, ns):
        return float(np.polyfit(np.log(ns[: len(ts)]), np.log(ts), 1)[0])

    emit("mra2_scaling_exponent", 0.0, f"{slope(times_mra, lens):.2f}")
    emit("full_scaling_exponent", 0.0, f"{slope(times_full, lens):.2f}")
