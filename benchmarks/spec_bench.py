"""Speculative-decoding benchmark: coarse-pyramid draft + chunked verify.

Drives Engine(spec_k=K) against the PR 3 engine baseline (spec_k=0) on the
same mixed workload and reports the serving economics of resolution
speculation (DESIGN.md §10):

  * acceptance rate vs K — how faithful the coarse pyramid level is as a
    draft model (drafts accepted / drafts offered);
  * accepted-tokens-per-dispatch vs K — decode-side tokens emitted per
    *full-MRA* dispatch (chunked verifies + any plain-decode fallback waves;
    drafts run the coarse-only O(S/b) path with no top-m gather). The
    baseline engine pays one full-attention decode dispatch per batched
    decode wave, so the comparison is the RATIO of the two economies on the
    same workload. The acceptance claim pinned here: >= 1.3x at K = 4 on
    the CI config;
  * end-to-end tok/s speedup vs the baseline engine. Reported honestly: on
    a CPU smoke model the draft forward costs nearly as much as the target
    forward (attention is a sliver of the FLOPs), so wall-clock speedup
    materializes only where full attention dominates (long contexts /
    accelerators); dispatch economy is the hardware-independent signal.

``--smoke`` (scripts/ci.sh fast tier) shrinks to K=2 and one workload so
the whole file runs in seconds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed import mesh_utils
from repro.models import get_model, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams


def _requests(rng, vocab):
    """Mixed greedy/sampled traffic; greedy-heavy like production serving."""
    reqs = []
    for i, (plen, new) in enumerate([(19, 16), (3, 12), (10, 16), (6, 10),
                                     (14, 12), (8, 14)]):
        sp = SamplingParams(temperature=0.8, top_k=8, seed=i) if i % 3 == 2 \
            else SamplingParams()
        reqs.append(Request(prompt=rng.integers(1, vocab, size=plen),
                            max_new_tokens=new, sampling=sp))
    return reqs


def _run_engine(cfg, params, rng, spec_k, mesh):
    eng = Engine(cfg, params, EngineConfig(
        slots=3, max_len=64, chunk=8, spec_k=spec_k, mesh=mesh))
    eng.run(_requests(rng, cfg.vocab)[:1])  # warmup: compile all dispatches
    eng.reset_stats()
    t0 = time.perf_counter()
    done = eng.run(_requests(rng, cfg.vocab))
    dt = time.perf_counter() - t0
    assert len(done) == 6
    return eng, done, dt


def run(emit, ks=(2, 4), assert_claim=True):
    mesh = mesh_utils.get_mesh()
    cfg = get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(attn_shard=mesh is not None)
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))

    base_eng, base_done, base_dt = _run_engine(
        cfg, params, np.random.default_rng(0), 0, mesh)
    n_req = len(base_done)
    base_gen = base_eng.stats["generated_tokens"]
    base_tps = base_gen / base_dt
    # decode-side dispatch economy: each request's first token rides on a
    # prefill dispatch, the rest cost one full-attention decode wave each
    base_per_dispatch = ((base_gen - n_req)
                         / max(base_eng.stats["decode_dispatches"], 1))
    emit("spec_base_tok_per_dispatch", base_dt / base_gen * 1e6,
         f"{base_per_dispatch:.2f}")
    emit("spec_base_tok_per_s", base_dt / base_gen * 1e6, f"{base_tps:.1f}")

    for k in ks:
        eng, done, dt = _run_engine(cfg, params, np.random.default_rng(0), k,
                                    mesh)
        st = eng.stats
        # greedy requests must be bit-identical to the baseline engine
        base_by = {len(r.prompt): r.out for r in base_done}
        for r in done:
            if r.sampling.temperature <= 0:
                assert np.array_equal(r.out, base_by[len(r.prompt)]), \
                    (r.out, base_by[len(r.prompt)])
        accept_rate = st["spec_accepted_tokens"] / max(st["spec_drafted_tokens"], 1)
        gen = st["generated_tokens"]
        # full-MRA dispatches on the decode side: chunked verifies + any
        # plain-decode fallback waves (ring-boundary slots)
        full_disp = st["verify_dispatches"] + st["decode_dispatches"]
        per_dispatch = (gen - n_req) / max(full_disp, 1)
        gain = per_dispatch / base_per_dispatch
        emit(f"spec_k{k}_accept_rate", dt / max(gen, 1) * 1e6,
             f"{accept_rate:.3f}")
        # per-slot acceptance series (serve/telemetry.py, DESIGN.md §13) —
        # the signal the adaptive-K arc tunes from: mean accepted drafts
        # per round for each scheduler slot
        series = eng.telemetry.snapshot()["series"]["spec_accept_by_slot"]
        emit(f"spec_k{k}_accept_per_slot", dt / max(gen, 1) * 1e6,
             " ".join(f"slot{s}={np.mean(v):.2f}/round"
                      for s, v in sorted(series.items())))
        assert series, "speculative engine recorded no per-slot acceptance"
        emit(f"spec_k{k}_tok_per_dispatch", dt / max(gen, 1) * 1e6,
             f"{per_dispatch:.2f}")
        emit(f"spec_k{k}_dispatch_gain_vs_base", dt / max(gen, 1) * 1e6,
             f"{gain:.2f}x")
        emit(f"spec_k{k}_tok_per_s", dt / max(gen, 1) * 1e6,
             f"{gen / dt:.1f}")
        emit(f"spec_k{k}_speedup_vs_base", dt / max(gen, 1) * 1e6,
             f"{(gen / dt) / base_tps:.2f}x")
        if assert_claim and k == 4:
            # acceptance criterion: >= 1.3 accepted-tokens-per-dispatch over
            # the PR 3 engine at K=4
            assert gain >= 1.3, (gain, dict(
                (kk, vv) for kk, vv in st.items()
                if kk != "decode_step_seconds"))


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast tier: K=2 only, no K=4 claim assert")
    args = ap.parse_args()

    from repro.launch.mesh import parse_mesh

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    with mesh_utils.use_mesh(parse_mesh(args.mesh)):
        if args.smoke:
            run(emit, ks=(2,), assert_claim=False)
        else:
            run(emit)


if __name__ == "__main__":
    main()
