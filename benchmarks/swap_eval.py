"""Paper Tab. 1/2 protocol, miniaturized: train with exact attention, then
*swap in* each efficient-attention module and measure NLL degradation.

The paper's headline compatibility claim: MRA-2(-s) can replace softmax
attention in a pretrained model nearly for free (MLM 71.9 vs 73.1), while
Linformer/Performer collapse without retraining. We reproduce the ordering
with a small LM trained from scratch on the synthetic corpus.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ShapeCfg
from repro.core.attention import AttentionSpec
from repro.data import make_batch
from repro.models import get_model, init_params
from repro.optim import AdamW, cosine_schedule
from repro.train import TrainConfig, make_train_step

SHAPE = ShapeCfg("swap", 128, 8, "train")
STEPS = 200  # enough for structured (copy-task) attention to sharpen


def run(emit):
    cfg = get_smoke_config("qwen3-1.7b").replace(
        attention=AttentionSpec(kind="full"))
    model = get_model(cfg)
    opt = AdamW(weight_decay=0.01)
    tc = TrainConfig(steps=STEPS, lr=3e-3, warmup=5)
    step = jax.jit(make_train_step(cfg, tc, opt, cosine_schedule(3e-3, 5, STEPS)))
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    state = opt.init(params)
    for s in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, step=s).items()}
        params, state, metrics = step(params, state, batch)
    emit("swap_train_final_loss", 0.0, f"{float(metrics['loss']):.4f}")

    eval_batch = {k: jnp.asarray(v)
                  for k, v in make_batch(cfg, SHAPE, step=10_000).items()}
    base_nll = float(model.loss_fn(params, cfg, eval_batch)[1]["nll"])
    emit("swap_eval_full", 0.0, f"{base_nll:.4f}")

    swaps = {
        "mra2": AttentionSpec(kind="mra2", block_size=16, blocks_per_row=4),
        "mra2_s": AttentionSpec(kind="mra2_s", block_size=16, blocks_per_row=4),
        "linformer": AttentionSpec(kind="linformer"),
        "performer": AttentionSpec(kind="performer"),
        "nystromformer": AttentionSpec(kind="nystromformer"),
        "longformer": AttentionSpec(kind="longformer"),
    }
    results = {}
    for name, spec in swaps.items():
        cfg_swap = cfg.replace(attention=spec)
        nll = float(get_model(cfg_swap).loss_fn(params, cfg_swap, eval_batch)[1]["nll"])
        results[name] = nll
        emit(f"swap_eval_{name}", 0.0, f"{nll:.4f}")
    # the paper's compatibility ordering: MRA degrades far less than the
    # low-rank family when dropped into trained weights
    ok = (results["mra2"] - base_nll) < 0.5 * (results["performer"] - base_nll)
    emit("swap_mra2_beats_lowrank", 0.0, str(bool(ok)))
