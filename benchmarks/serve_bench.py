"""Serving-engine benchmark: continuous batching under mixed traffic.

Drives the full Engine (chunked prefill + ragged decode + sampling) on a
smoke-scale model and reports production serving metrics:

  * requests/sec and generated tokens/sec vs. slot count,
  * p50 / p99 inter-token latency (wall time of each batched decode step),
  * jitted-dispatch economy of chunked prefill vs. the token-replay
    baseline (one decode dispatch per prompt token — what the engine did
    before DESIGN.md §9): the acceptance claim is >= 5x fewer dispatches
    for a 128-token prompt.

Mesh-aware like decode_bench: under ``--mesh DxM`` the engine places
params/KV by ParamSpec axes and serves tensor-parallel.

Also serves the recurrent/hybrid families (rwkv6, recurrentgemma) through
the same engine via the per-layer cache protocol (DESIGN.md §12), reporting
req/s, tok/s, and the chunked-recurrent-prefill dispatch ratio vs. token
replay (acceptance: >= 5x).

Telemetry rows (DESIGN.md §13): TTFT p50/p99 and queue-wait from the
request-lifecycle histograms, cache-occupancy peaks for all three cache
families, per-slot speculative acceptance, and the pinned no-op-path
overhead claim — telemetry-on vs telemetry-off tok/s ratio >= 0.95 with
bit-identical token streams. ``--trace out.jsonl`` (or ``benchmarks.run
--trace``) exports the speculative engine's Chrome-trace JSONL as the CI
artifact.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed import mesh_utils
from repro.models import get_model, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams
from repro.serve.telemetry import load_trace_jsonl, validate_chrome_events


def _requests(rng, vocab, lens, new_tokens):
    reqs = []
    for i, ln in enumerate(lens):
        sp = SamplingParams(temperature=0.8, top_k=8, seed=i) if i % 2 else \
            SamplingParams()
        reqs.append(Request(prompt=rng.integers(1, vocab, size=ln),
                            max_new_tokens=new_tokens, sampling=sp))
    return reqs


def _long_ctx(emit, cfg, params, mesh, *, smoke):
    """H=3 collapse-up serving (DESIGN.md §14): context >> the fine window.

    One slot streams a prompt far past ``max_len`` through chunked prefill —
    every evicted page collapses into the int8/int4 level rings + fp32 tail
    instead of vanishing — then decodes from the collapsed state. The row's
    throughput is context tokens processed per second (prefill-dominated);
    the derived column pins the memory claim: live fine tokens stay bounded
    by the window while the tail absorbs the distant history. The smoke
    variant (scripts/ci.sh fast) shrinks the stream and routes attention
    through the interpret-mode serving kernel so the in-kernel upper-level
    fold is exercised end-to-end off-TPU.
    """
    hcfg = cfg.replace(attention=cfg.attention.replace(levels=3))
    if smoke:
        hcfg = hcfg.replace(attn_use_kernel=True,
                            attn_interpret=jax.devices()[0].platform != "tpu")
    S, max_len, chunk = (2048, 256, 128) if smoke else (65536, 1024, 512)
    rng = np.random.default_rng(42)
    eng = Engine(hcfg, params, EngineConfig(
        slots=1, max_len=max_len, chunk=chunk, mesh=mesh))
    req = Request(prompt=rng.integers(1, cfg.vocab, size=S), max_new_tokens=4)
    t0 = time.perf_counter()
    done = eng.run([req])
    dt = time.perf_counter() - t0
    assert len(done) == 1 and len(done[0].out) == req.max_new_tokens
    g = eng.telemetry.snapshot()["gauges"]
    live = g["cache_tokens_live"]["peak"]
    tail = g["cache_tail_tokens"]["peak"]
    assert g["cache_level2_entries"]["peak"] > 0, "no collapsed entries"
    assert tail > 0, "long context never reached the tail"
    assert live <= max_len, (live, max_len)
    tok = S + len(done[0].out)
    tag = "serve_longctx_smoke" if smoke else "serve_longctx"
    emit(f"{tag}_tok_per_s", dt / tok * 1e6,
         f"{tok / dt:.0f} ctx={S} window={max_len} live_peak={live:.0f} "
         f"tail_peak={tail:.0f}")


def run(emit, trace_path=None):
    mesh = mesh_utils.get_mesh()
    cfg = get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(attn_shard=mesh is not None)
    params = init_params(get_model(cfg).param_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    chunk = 32
    new_tokens = 8

    # prompt-length mix: short chat-style + long document-style
    mixes = {"short": [8, 12, 5, 9, 14, 7], "mixed": [8, 128, 24, 96, 12, 64]}
    ttft_all, queue_all = [], []
    for slots in (2, 4):
        for mix_name, lens in mixes.items():
            eng = Engine(cfg, params, EngineConfig(
                slots=slots, max_len=256, chunk=chunk, mesh=mesh))
            reqs = _requests(rng, cfg.vocab, lens, new_tokens)
            eng.run(reqs[:1])  # warmup: compile prefill + decode + sample
            eng.reset_stats()
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt = time.perf_counter() - t0
            assert len(done) == len(reqs)
            gen = eng.stats["generated_tokens"]
            snap = eng.telemetry.snapshot()
            itl = snap["histograms"]["decode_step_seconds"]
            name = f"serve_s{slots}_{mix_name}"
            emit(f"{name}_req_per_s", dt / max(len(reqs), 1) * 1e6,
                 f"{len(reqs) / dt:.2f}")
            emit(f"{name}_tok_per_s", dt / max(gen, 1) * 1e6, f"{gen / dt:.1f}")
            emit(f"{name}_itl_p50", itl["p50"] * 1e6,
                 f"{itl['p50'] * 1e3:.2f}ms")
            emit(f"{name}_itl_p99", itl["p99"] * 1e6,
                 f"{itl['p99'] * 1e3:.2f}ms")
            ttft_all += eng.stats["ttft_seconds"]
            queue_all += eng.stats["queue_wait_seconds"]

    # request-lifecycle telemetry across the slot/mix sweep (DESIGN.md §13):
    # TTFT = submit -> first token, decomposable into queue + prefill via the
    # queue_wait/prefill histograms the same snapshot carries
    def pct(xs, q):
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(len(xs) * q))] if xs else 0.0

    ttft_p50, ttft_p99 = pct(ttft_all, 0.5), pct(ttft_all, 0.99)
    emit("serve_ttft_p50", ttft_p50 * 1e6, f"{ttft_p50 * 1e3:.2f}ms")
    emit("serve_ttft_p99", ttft_p99 * 1e6, f"{ttft_p99 * 1e3:.2f}ms")
    emit("serve_queue_wait_p50", pct(queue_all, 0.5) * 1e6,
         f"{pct(queue_all, 0.5) * 1e3:.2f}ms")
    assert len(ttft_all) >= 4 * len(mixes["short"]) - 4, len(ttft_all)
    # ring-paged cache occupancy peaks from the last (s4, mixed) run
    g = snap["gauges"]
    emit("serve_cache_occupancy", dt * 1e6,
         f"pages_live_peak={g['cache_pages_live']['peak']:.0f} "
         f"tokens_live_peak={g['cache_tokens_live']['peak']:.0f} "
         f"evicted_peak={g['cache_tokens_evicted']['peak']:.0f}")
    assert g["cache_pages_live"]["peak"] > 0

    # no-op fast path (DESIGN.md §13): telemetry must be a pure observer —
    # token streams bit-identical with it on or off, and the enabled path's
    # throughput within a few percent. Best-of-3 guards CPU timer noise.
    def overhead_leg(telemetry_on):
        eng = Engine(cfg, params, EngineConfig(
            slots=4, max_len=256, chunk=chunk, mesh=mesh,
            telemetry=telemetry_on))
        mk = lambda: _requests(np.random.default_rng(7), cfg.vocab,
                               mixes["short"], new_tokens)  # noqa: E731
        eng.run(mk()[:1])  # warmup: compile prefill + decode + sample
        best_tps, done = 0.0, None
        for _ in range(3):
            reqs = mk()
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt_leg = time.perf_counter() - t0
            gen_leg = sum(len(r.out) for r in done)
            best_tps = max(best_tps, gen_leg / dt_leg)
        return best_tps, {len(r.prompt): r.out for r in done}

    off_tps, off_out = overhead_leg(False)
    on_tps, on_out = overhead_leg(True)
    match = all(np.array_equal(on_out[k], off_out[k]) for k in off_out)
    ratio = on_tps / off_tps
    emit("serve_telemetry_overhead_ratio", 1e6 / max(on_tps, 1e-9),
         f"{ratio:.3f} tokens_match={match}")
    assert match, "telemetry changed the token stream"
    assert ratio >= 0.95, (on_tps, off_tps)

    # dispatch economy: one 128-token prompt through chunked prefill vs. the
    # token-replay baseline (= prompt_len decode dispatches, the pre-§9 engine)
    eng = Engine(cfg, params, EngineConfig(
        slots=2, max_len=256, chunk=chunk, mesh=mesh))
    prompt_len = 128
    t0 = time.perf_counter()
    eng.run([Request(prompt=rng.integers(1, cfg.vocab, size=prompt_len),
                     max_new_tokens=2)])
    dt = time.perf_counter() - t0
    chunked = eng.stats["prefill_dispatches"]
    replay = prompt_len  # baseline: one whole-batch decode dispatch per token
    ratio = replay / max(chunked, 1)
    emit(f"serve_prefill_dispatches_p{prompt_len}", dt * 1e6,
         f"{chunked} vs {replay} replay ({ratio:.0f}x fewer)")
    assert ratio >= 5.0, (chunked, replay)

    # fused Pallas serving kernel (DESIGN.md §11): the same engine with
    # chunked prefill + decode attention routed through kernels/chunk_attn.py
    # (interpret mode off-TPU, so treat the CPU tok/s as a does-it-run row,
    # not a speedup claim; the derived column pins the token streams equal).
    interpret = jax.devices()[0].platform != "tpu"
    kcfg = cfg.replace(attn_use_kernel=True, attn_interpret=interpret)
    lens = [8, 12, 5]
    reqs = _requests(rng, cfg.vocab, lens, new_tokens)
    ref = Engine(cfg, params, EngineConfig(
        slots=2, max_len=64, chunk=8, mesh=mesh)).run(
        [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                 sampling=r.sampling) for r in reqs])
    eng = Engine(kcfg, params, EngineConfig(
        slots=2, max_len=64, chunk=8, mesh=mesh))
    eng.run(reqs[:1])  # warmup: compile the kernel-path prefill + decode
    eng.reset_stats()
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    gen = eng.stats["generated_tokens"]
    by = {len(r.prompt): r.out for r in ref}
    match = all(np.array_equal(r.out, by[len(r.prompt)]) for r in done)
    emit("serve_kernel_tok_per_s", dt / max(gen, 1) * 1e6,
         f"{gen / dt:.1f} tokens_match={match}")
    assert match

    # dual-mode contract (DESIGN.md §11): forcing either tile mode for every
    # dispatch must leave the engine's token streams bit-identical to the
    # jnp reference — the mode is a performance knob, never a numerics knob.
    for mode in ("latency", "throughput"):
        eng = Engine(kcfg, params, EngineConfig(
            slots=2, max_len=64, chunk=8, mesh=mesh, kernel_mode=mode))
        eng.run(reqs[:1])  # warmup: compile the forced-mode executables
        eng.reset_stats()
        t0 = time.perf_counter()
        done = eng.run(reqs)
        dt = time.perf_counter() - t0
        gen = eng.stats["generated_tokens"]
        match = all(np.array_equal(r.out, by[len(r.prompt)]) for r in done)
        emit(f"serve_kernel_{mode}_tok_per_s", dt / max(gen, 1) * 1e6,
             f"{gen / dt:.1f} tokens_match={match}")
        assert match, mode

    # resolution-speculative engine telemetry (DESIGN.md §10/§13): per-slot
    # acceptance series land in the snapshot, and this engine's trace — the
    # richest lifecycle (queued/prefill/decode spans + draft/verify
    # dispatches) — is the exported Chrome-trace JSONL artifact.
    seng = Engine(cfg, params, EngineConfig(
        slots=2, max_len=64, chunk=8, spec_k=2, mesh=mesh))
    sreqs = [Request(prompt=rng.integers(1, cfg.vocab, size=ln),
                     max_new_tokens=12) for ln in (19, 7, 11, 5)]
    seng.run([Request(prompt=rng.integers(1, cfg.vocab, size=6),
                      max_new_tokens=4)])  # warmup
    seng.reset_stats()
    t0 = time.perf_counter()
    sdone = seng.run(sreqs)
    dt = time.perf_counter() - t0
    assert len(sdone) == len(sreqs)
    snap = seng.telemetry.snapshot()
    series = snap["series"]["spec_accept_by_slot"]
    per_slot = " ".join(
        f"slot{k}={np.mean(v):.2f}/round" for k, v in sorted(series.items()))
    emit("serve_spec_accept_per_slot", dt * 1e6, per_slot or "none")
    assert series, "speculative run recorded no per-slot acceptance"
    if trace_path:
        n = seng.telemetry.trace.export_jsonl(trace_path)
        validate_chrome_events(load_trace_jsonl(trace_path))
        emit("serve_trace_events", dt * 1e6,
             f"{n} events -> {trace_path} (validated)")

    # H=3 collapse-up long context (DESIGN.md §14): a 64k-token stream
    # served from a 1k-token fine window — the REQUIRED_ROWS memory claim
    _long_ctx(emit, cfg, params, mesh, smoke=False)

    # recurrent/hybrid families through the same engine (DESIGN.md §12):
    # rwkv6's O(1) wkv state and recurrentgemma's RG-LRU + window ring serve
    # under identical continuous batching; the dispatch-economy claim is the
    # chunked recurrent prefill vs. token-by-token state replay
    for arch in ("rwkv6-7b", "recurrentgemma-9b"):
        rcfg = get_smoke_config(arch).replace(attn_shard=mesh is not None)
        rparams = init_params(get_model(rcfg).param_specs(rcfg),
                              jax.random.PRNGKey(0))
        lens = [8, 96, 24, 64, 12, 48]
        eng = Engine(rcfg, rparams, EngineConfig(
            slots=4, max_len=256, chunk=chunk, mesh=mesh))
        reqs = _requests(rng, rcfg.vocab, lens, new_tokens)
        eng.run(reqs[:1])  # warmup: compile prefill + decode + sample
        eng.reset_stats()
        t0 = time.perf_counter()
        done = eng.run(reqs)
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        gen = eng.stats["generated_tokens"]
        pre_tok = eng.stats["prefill_tokens"]
        pre_disp = eng.stats["prefill_dispatches"]
        ratio = pre_tok / max(pre_disp, 1)
        tag = arch.split("-")[0]
        emit(f"serve_{tag}_req_per_s", dt / max(len(reqs), 1) * 1e6,
             f"{len(reqs) / dt:.2f}")
        emit(f"serve_{tag}_tok_per_s", dt / max(gen, 1) * 1e6,
             f"{gen / dt:.1f}")
        emit(f"serve_{tag}_prefill_dispatch_ratio", dt * 1e6,
             f"{pre_disp} dispatches for {pre_tok} tokens "
             f"({ratio:.0f}x fewer than replay)")
        assert ratio >= 5.0, (pre_disp, pre_tok)
        # state/window cache occupancy (DESIGN.md §13): recurrent state
        # absorbs history (evicted stays 0); the hybrid window ring holds
        # min(L, W) entries and counts older positions as evicted
        g = eng.telemetry.snapshot()["gauges"]
        emit(f"serve_{tag}_cache_occupancy", dt * 1e6,
             f"tokens_live_peak={g['cache_tokens_live']['peak']:.0f} "
             f"pages_live_peak={g['cache_pages_live']['peak']:.0f} "
             f"evicted_peak={g['cache_tokens_evicted']['peak']:.0f}")
        assert g["cache_tokens_live"]["peak"] > 0


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    ap.add_argument("--trace", default=None,
                    help="export the speculative engine's request/dispatch "
                         "trace as Chrome-trace JSONL to this path")
    ap.add_argument("--long-ctx-smoke", action="store_true",
                    help="run only the H=3 collapse-up long-context smoke "
                         "(small stream, interpret-mode kernel; the "
                         "scripts/ci.sh fast leg)")
    args = ap.parse_args()

    from repro.launch.mesh import parse_mesh

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    with mesh_utils.use_mesh(parse_mesh(args.mesh)):
        if args.long_ctx_smoke:
            mesh = mesh_utils.get_mesh()
            cfg = get_smoke_config("qwen3-1.7b").replace(
                attn_shard=mesh is not None)
            params = init_params(get_model(cfg).param_specs(cfg),
                                 jax.random.PRNGKey(0))
            _long_ctx(emit, cfg, params, mesh, smoke=True)
        else:
            run(emit, trace_path=args.trace)


if __name__ == "__main__":
    main()
