"""Paper Fig. 1 + Fig. 4 / Tab. 7: approximation error vs budget vs baselines.

Fig. 1 claim to reproduce: keeping ~10% of {MRA coefficients, ranks, nonzero
entries} gives errors ~{0.30, 1.24, 0.39} — i.e. MRA < sparse < low-rank on a
representative attention matrix. We check the ORDERING and that MRA at a 10%
entry budget reaches a comparable error band on structured attention.
"""
from __future__ import annotations

import numpy as np

from repro.core.attention import AttentionSpec, self_attention
from repro.core.mra import MraConfig, full_attention, mra2_attention

from .common import rel_error, structured_qkv, time_call


def fig1_scores(rng, N=512, sharp=3.0):
    """Representative attention scores: sharp banded diagonal of varying width
    (full-rank structure), a few global key columns, contiguous content
    clusters, token noise. Matches the block-local-smoothness (locality)
    regime the paper's Lemma 4.1 assumes for trained models.
    """
    i = np.arange(N)[:, None]
    j = np.arange(N)[None, :]
    w = 8 + 24 * (0.5 + 0.5 * np.sin(2 * np.pi * i / N * 3))
    P = 1.5 * np.exp(-((i - j).astype(np.float64) ** 2) / (2 * w**2))
    for g in rng.integers(0, N, 6):
        P[:, g] += 0.7 + 0.2 * rng.standard_normal()
    nclust = 10
    bounds = np.sort(rng.integers(0, N, nclust - 1))
    bounds = np.r_[0, bounds, N]
    cid = np.zeros(N, int)
    for c in range(nclust):
        cid[bounds[c]:bounds[c + 1]] = c
    P += 0.3 * rng.standard_normal((nclust, nclust))[cid[:, None], cid[None, :]]
    P += 0.2 * rng.standard_normal((N, N))
    return P * sharp


def fig1_matrix_level(rng, N=512, keep=0.10, block=32):
    """Matrix-level comparison on A = exp(P) at a shared 10% budget.

    Returns (mra, svd, nystrom, sparse) relative Frobenius errors. Notes:
      * SVD is the *information-theoretic optimum* for low rank — far
        stronger than any practical method; the paper's 1.24 corresponds to
        realizable low-rank, which Nystrom represents here (it explodes).
      * top-entry sparsity here is an O(n^2) *oracle* (needs the full
        matrix); practical sparse methods are compared in the Fig-4 rows.
    """
    P = fig1_scores(rng, N)
    P = P - P.max()
    A = np.exp(P)
    fro = np.linalg.norm(A)
    nb = N // block
    m = max(int(keep * N * N / (block * block)), 1)
    mu = np.exp(P.reshape(nb, block, nb, block).mean((1, 3)))  # coarse mu (eq. 6)
    order = np.argsort(mu, axis=None)[::-1]
    A_mra = np.repeat(np.repeat(mu, block, 0), block, 1)
    for idx in order[:m]:
        x, y = divmod(int(idx), nb)
        A_mra[x * block:(x + 1) * block, y * block:(y + 1) * block] = \
            A[x * block:(x + 1) * block, y * block:(y + 1) * block]
    err_mra = np.linalg.norm(A_mra - A) / fro

    r = max(int(keep * N), 1)
    U, S, Vt = np.linalg.svd(A, full_matrices=False)
    err_svd = np.linalg.norm((U[:, :r] * S[:r]) @ Vt[:r] - A) / fro

    cols = rng.choice(N, r, replace=False)
    C = A[:, cols]
    W = A[np.ix_(cols, cols)]
    A_nys = C @ np.linalg.pinv(W, rcond=1e-8) @ A[cols, :]
    err_nys = np.linalg.norm(A_nys - A) / fro

    kth = np.partition(A.flatten(), -int(keep * N * N))[-int(keep * N * N)]
    err_sp = np.linalg.norm(np.where(A >= kth, A, 0.0) - A) / fro
    return err_mra, err_svd, err_nys, err_sp


def run(emit):
    rng = np.random.default_rng(0)

    errs = np.mean([fig1_matrix_level(np.random.default_rng(s)) for s in range(5)],
                   axis=0)
    err_mra, err_svd, err_nys, err_sp = errs
    emit("fig1_err_mra_10pct", 0.0, f"{err_mra:.3f}")
    emit("fig1_err_lowrank_svd_10pct", 0.0, f"{err_svd:.3f}")
    emit("fig1_err_lowrank_nystrom_10pct", 0.0, f"{err_nys:.3f}")
    emit("fig1_err_sparse_oracle_10pct", 0.0, f"{err_sp:.3f}")
    emit("fig1_mra_beats_practical_lowrank", 0.0, str(bool(err_mra < err_nys)))
    emit("fig1_mra_beats_optimal_svd", 0.0, str(bool(err_mra < err_svd)))

    # Fig. 4 / Tab. 7 protocol: error + runtime per method at N=512
    q, k, v = structured_qkv(rng, B=1, H=8, N=512, D=64)
    # coarse-only fidelity (DESIGN.md §10): the speculative draft attends its
    # own block exactly and everything else through the pyramid sums alone —
    # this error is what bounds the draft's acceptance rate
    spec_c = AttentionSpec(kind="mra2", block_size=32, coarse_only=True)
    us = time_call(lambda q, k, v: self_attention(q, k, v, spec_c), q, k, v)
    err = rel_error(self_attention(q, k, v, spec_c), q, k, v)
    emit("mra2_coarse_only_n512", us, f"{err:.4f}")
    # same comparison on the decode path the draft actually runs: one query
    # against a 512-token cache, coarse-only vs the exact decode oracle
    from repro.core.mra import MraConfig
    from repro.core.mra_decode import (full_decode_attention,
                                       mra2_coarse_decode_attention)

    qd = q[:, :, -1:, :]
    lengths = np.full((q.shape[0],), q.shape[2], np.int32)
    mcfg = MraConfig(block_size=32, causal=True)
    approx = mra2_coarse_decode_attention(qd, k, v, lengths, mcfg)
    exact = full_decode_attention(qd, k, v, lengths)
    err_d = float(np.linalg.norm(np.asarray(approx) - np.asarray(exact))
                  / (np.linalg.norm(np.asarray(exact)) + 1e-9))
    us = time_call(
        lambda q_, k_, v_: mra2_coarse_decode_attention(q_, k_, v_, lengths, mcfg),
        qd, k, v)
    emit("mra2_coarse_decode_n512", us, f"{err_d:.4f}")
    for bpr in (1, 2, 4, 8):
        cfg = MraConfig(block_size=32, blocks_per_row=bpr)
        us = time_call(lambda q, k, v: mra2_attention(q, k, v, cfg), q, k, v)
        err = rel_error(mra2_attention(q, k, v, cfg), q, k, v)
        emit(f"mra2_b32_bpr{bpr}_n512", us, f"{err:.4f}")
        cfg_s = MraConfig(block_size=32, blocks_per_row=bpr, variant="sparse")
        us = time_call(lambda q, k, v: mra2_attention(q, k, v, cfg_s), q, k, v)
        err = rel_error(mra2_attention(q, k, v, cfg_s), q, k, v)
        emit(f"mra2s_b32_bpr{bpr}_n512", us, f"{err:.4f}")

    for kind, kw in [("linformer", {}), ("performer", {}), ("nystromformer", {}),
                     ("longformer", {}), ("bigbird", {}),
                     ("h_transformer_1d", {})]:
        spec = AttentionSpec(kind=kind, **kw)
        us = time_call(lambda q, k, v: self_attention(q, k, v, spec), q, k, v)
        err = rel_error(self_attention(q, k, v, spec), q, k, v)
        emit(f"{kind}_n512", us, f"{err:.4f}")

    us = time_call(lambda q, k, v: full_attention(q, k, v), q, k, v)
    emit("full_attention_n512", us, "0.0000")

    # H-level pyramid error (DESIGN.md §14): decode against a long stream
    # served from a fine window 8x smaller than the context. H=2 is today's
    # ring — evicted history vanishes entirely; H>=3 keeps it as collapsed
    # (int8 / int4) background mass, so error vs the exact softmax over the
    # FULL stream should drop monotonically as levels are added.
    import jax.numpy as jnp

    from repro.core import hier
    from repro.core.mra_decode import PyramidState, mra2_chunk_attention

    S_total, block, nb = 2048, 32, 8  # window = 256 tokens
    qh, kh, vh = structured_qkv(rng, B=1, H=4, N=S_total, D=32)
    qd = jnp.asarray(qh[:, :, -1:, :])
    lengths = jnp.full((1,), S_total, jnp.int32)
    q_pos = jnp.full((1, 1), S_total - 1, jnp.int32)
    exact = np.asarray(full_decode_attention(qd, jnp.asarray(kh),
                                             jnp.asarray(vh), lengths))
    hcfg = MraConfig(block_size=block, causal=True)
    for H in (2, 3, 4):
        cache = hier.build_hier_stream(jnp.asarray(kh), jnp.asarray(vh),
                                       block=block, nb=nb, levels=H)
        pyr = PyramidState(cache["pyr_k"][0], cache["pyr_v"][0],
                           hier.cache_upper_view(cache, 0))
        run_h = lambda q_: mra2_chunk_attention(  # noqa: E731
            q_, cache["k_cache"], cache["v_cache"], lengths, q_pos, hcfg,
            decode_blocks=4, pyramid=pyr, page_blocks=cache["page_blocks"])
        us = time_call(run_h, qd)
        err = float(np.linalg.norm(np.asarray(run_h(qd)) - exact)
                    / (np.linalg.norm(exact) + 1e-9))
        emit(f"hier_decode_err_h{H}_n{S_total}_w{nb * block}", us,
             f"{err:.4f}")
