"""Kernel-vs-jnp timing: fwd and fwd+bwd through the MRA-2 attention paths.

Times three routes over the same inputs/selection budget:

  * jnp           — pure gather/scatter path (mra2_attention, no kernel)
  * kernel        — Pallas fwd + fused Pallas bwd (interpret mode off-TPU)
  * kernel_jnpbwd — Pallas fwd + jnp fallback bwd (the dispatch boundary)

plus the serving-side twin (PR 5, DESIGN.md §11): chunk/decode attention
against a KV cache through the fused Pallas serving kernel vs. the pure-jnp
gather path, with the max |out| difference as the online parity check. The
serving kernel is dual-mode (PR 7): ``kernel_mode="auto"`` resolves decode
to latency (single-query) tiles and chunks to throughput (multi-query MXU)
tiles; extra rows force each mode on the chunk shape to price the tile
choice and pin both against the jnp oracle.

On a CPU host the Pallas kernels run in interpret mode, so the absolute
numbers only demonstrate that the paths execute end-to-end; the
kernel-vs-jnp *ratio* is only meaningful on a real TPU, where interpret
flips to False automatically. The derived column reports the max |grad|
difference vs the jnp path (a cheap online correctness check).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec, chunk_attention, decode_attention
from repro.core.mra import MraConfig, mra2_attention

from .common import structured_qkv, time_call


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def run(emit):
    rng = np.random.default_rng(5)
    interpret = not _on_tpu()
    # interpret mode executes the kernel body per grid step in Python — keep
    # the CPU shape small; TPU runs get a production-ish shape.
    N, H, D, b = (512, 4, 64, 32) if _on_tpu() else (128, 2, 16, 16)
    q, k, v = structured_qkv(rng, B=1, H=H, N=N, D=D)

    def cfg(use_kernel, bwd="pallas"):
        return MraConfig(block_size=b, blocks_per_row=4, causal=True,
                         use_kernel=use_kernel, kernel_bwd=bwd,
                         interpret=interpret)

    routes = {
        "jnp": cfg(False),
        "kernel": cfg(True),
        "kernel_jnpbwd": cfg(True, bwd="jnp"),
    }

    def loss_fn(c):
        return lambda q, k, v: jnp.sum(jnp.tanh(mra2_attention(q, k, v, c)))

    grads = {}
    for name, c in routes.items():
        us_f = time_call(lambda q, k, v: mra2_attention(q, k, v, c), q, k, v)
        emit(f"kernel_bench_fwd_{name}", us_f, f"interpret={interpret}")
        gfn = jax.jit(jax.grad(loss_fn(c), argnums=(0, 1, 2)))
        grads[name] = jax.block_until_ready(gfn(q, k, v))  # doubles as warmup
        us_b = time_call(gfn, q, k, v)
        emit(f"kernel_bench_fwdbwd_{name}", us_b, f"interpret={interpret}")

    # online parity check: kernel-route grads vs the jnp path
    for name in ("kernel", "kernel_jnpbwd"):
        diff = max(
            float(jnp.abs(a - b).max())
            for a, b in zip(grads[name], grads["jnp"])
        )
        emit(f"kernel_bench_graddiff_{name}", 0.0, f"{diff:.2e}")

    # ---- serving kernel: chunk/decode attention vs the KV cache (§11) ----- #
    B, Hq, Hkv, S, Dd, bd, C, m = (
        (4, 8, 2, 2048, 64, 32, 16, 16) if _on_tpu() else
        (2, 4, 2, 128, 16, 16, 8, 4))
    _, kc, vc = structured_qkv(rng, B=B, H=Hkv, N=S, D=Dd)
    lengths = jnp.full((B,), S, jnp.int32)
    q_pos = jnp.broadcast_to(jnp.arange(S - C, S), (B, C))
    qc = jnp.asarray(rng.standard_normal((B, Hq, C, Dd)), jnp.float32)
    q1 = qc[:, :, :1]
    for route, use_kernel in (("jnp", False), ("kernel", True)):
        spec = AttentionSpec(kind="mra2", block_size=bd, decode_blocks=m,
                             use_kernel=use_kernel, interpret=interpret)
        us = time_call(
            lambda q: decode_attention(q, kc, vc, lengths, spec), q1)
        emit(f"kernel_bench_decode_{route}", us, f"interpret={interpret}")
        us = time_call(
            lambda q: chunk_attention(q, kc, vc, lengths, q_pos, spec), qc)
        emit(f"kernel_bench_chunk_c{C}_{route}", us, f"interpret={interpret}")
    spec_j = AttentionSpec(kind="mra2", block_size=bd, decode_blocks=m)
    spec_k = spec_j.replace(use_kernel=True, interpret=interpret)
    diff = float(jnp.abs(
        chunk_attention(qc, kc, vc, lengths, q_pos, spec_k)
        - chunk_attention(qc, kc, vc, lengths, q_pos, spec_j)).max())
    emit("kernel_bench_chunk_outdiff_kernel", 0.0, f"{diff:.2e}")

    # forced tile modes (DESIGN.md §11): "auto" resolves decode to latency
    # tiles and chunks to throughput tiles, so the rows above already time
    # the production pairing. Forcing the off-diagonal — a C-token chunk
    # through latency (single-query) tiles — prices the MXU-shaped tile
    # against C single-row dispatch steps and pins both modes to the jnp
    # oracle on the same inputs.
    ref = chunk_attention(qc, kc, vc, lengths, q_pos, spec_j)
    for mode in ("latency", "throughput"):
        spec_m = spec_k.replace(kernel_mode=mode)
        us = time_call(
            lambda q: chunk_attention(q, kc, vc, lengths, q_pos, spec_m), qc)
        diff = float(jnp.abs(
            chunk_attention(qc, kc, vc, lengths, q_pos, spec_m) - ref).max())
        emit(f"kernel_bench_chunk_c{C}_kernel_{mode}", us,
             f"interpret={interpret} outdiff={diff:.2e}")
