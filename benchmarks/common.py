"""Shared benchmark utilities: structured QKV generators + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mra import full_attention


def structured_qkv(rng, B=1, H=8, N=512, D=64, *, n_clusters=12, locality=0.7,
                   n_global=4, scale=1.0):
    """Q/K/V that produce trained-transformer-like attention (paper Fig. 8):
    banded structure (positional drift), block structure (content clusters),
    and a few global columns. This is the offline stand-in for the paper's
    "Q, K, V from a pretrained model" protocol (§5.1).
    """
    t = np.linspace(0, 6 * np.pi, N)
    drift = np.stack([np.sin(t + p) for p in np.linspace(0, np.pi, D // 2)], -1)
    drift = np.concatenate([drift, np.cos(drift)], -1)[:, :D]  # (N, D)
    centers = rng.standard_normal((n_clusters, D))
    assign = np.sort(rng.integers(0, n_clusters, N))  # contiguous-ish clusters
    content_q = centers[assign] + 0.4 * rng.standard_normal((N, D))
    content_k = centers[assign] + 0.4 * rng.standard_normal((N, D))

    def mix(content):
        out = np.zeros((B, H, N, D), np.float32)
        for b in range(B):
            for h in range(H):
                w = locality * (0.5 + rng.random())
                noise = 0.3 * rng.standard_normal((N, D))
                out[b, h] = (w * drift + (1 - w) * content + noise) * scale
        return out

    q = mix(content_q)
    k = mix(content_k)
    # global tokens: a few keys with large norm attract most queries
    gidx = rng.integers(0, N, n_global)
    k[:, :, gidx] *= 3.0
    v = rng.standard_normal((B, H, N, D)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def rel_error(approx, q, k, v):
    """Paper's metric: ||D^A^V - DAV||_F / ||DAV||_F."""
    ref = full_attention(q, k, v)
    return float(jnp.linalg.norm(approx - ref) / jnp.linalg.norm(ref))


def time_call(fn, *args, iters=3, warmup=1):
    """Median wall time (us) of a jitted call on this host."""
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = jfn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
