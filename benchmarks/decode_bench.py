"""Beyond-paper: MRA decode (top-k KV-block selection) quality + cost.

Per decoded token, MRA decode reads O(S/b + m*b) of the KV cache instead of
O(S). This benchmark sweeps the exact-block budget m and reports the
attention-output error vs exact decode, plus host wall-time.

Mesh-aware: under an active mesh (``benchmarks/run.py --mesh DxM``, or this
module's own ``--mesh`` flag when run standalone) the query/cache tensors
are placed batch-over-data / kv-heads-over-model and the attention runs
through the shard_map TP decode path (distributed/shard_attn.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.attention import AttentionSpec, decode_attention
from repro.distributed import mesh_utils
from repro.distributed.shard_attn import attention_partition

from .common import structured_qkv, time_call


def run(emit):
    rng = np.random.default_rng(3)
    B, Hq, Hkv, S, D, b = 4, 8, 2, 4096, 64, 32
    _, k, v = structured_qkv(rng, B=B, H=Hkv, N=S, D=D)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)

    mesh = mesh_utils.get_mesh()
    shard = mesh is not None
    if shard:
        # place operands with the exact partition the shard_map in_specs will
        # use (distributed/shard_attn.py) — any other rule means a reshard on
        # entry and the benchmark would time data movement, not attention.
        parts = attention_partition(mesh, B, Hkv)
        if parts is not None:
            bpart, hpart = parts
            s4 = NamedSharding(mesh, P(bpart, hpart, None, None))
            q = jax.device_put(q, s4)
            k = jax.device_put(k, s4)
            v = jax.device_put(v, s4)
            lengths = jax.device_put(lengths, NamedSharding(mesh, P(bpart)))

    full_spec = AttentionSpec(kind="full", shard=shard)
    ref = decode_attention(q, k, v, lengths, full_spec)
    for m in (4, 16, 64):
        spec = AttentionSpec(kind="mra2", block_size=b, decode_blocks=m,
                             shard=shard)
        out = decode_attention(q, k, v, lengths, spec)
        err = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        us = time_call(
            lambda q, k, v: decode_attention(q, k, v, lengths, spec), q, k, v)
        emit(f"mra_decode_s4096_m{m}", us, f"{err:.4f}")
    us = time_call(
        lambda q, k, v: decode_attention(q, k, v, lengths, full_spec), q, k, v)
    emit("full_decode_s4096", us, "0.0000")


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    args = ap.parse_args()

    from repro.launch.mesh import parse_mesh

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    with mesh_utils.use_mesh(parse_mesh(args.mesh)):
        run(emit)


if __name__ == "__main__":
    main()
