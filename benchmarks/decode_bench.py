"""Beyond-paper: MRA decode (top-k KV-block selection) quality + cost.

Per decoded token, MRA decode reads O(S/b + m*b) of the KV cache instead of
O(S). This benchmark sweeps the exact-block budget m and reports the
attention-output error vs exact decode, plus host wall-time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.mra import MraConfig
from repro.core.mra_decode import full_decode_attention, mra2_decode_attention

from .common import structured_qkv, time_call


def run(emit):
    rng = np.random.default_rng(3)
    B, Hq, Hkv, S, D, b = 4, 8, 2, 4096, 64, 32
    _, k, v = structured_qkv(rng, B=B, H=Hkv, N=S, D=D)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    ref = full_decode_attention(q, k, v, lengths)
    cfg = MraConfig(block_size=b)
    for m in (4, 16, 64):
        out = mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=m)
        err = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        us = time_call(
            lambda q, k, v: mra2_decode_attention(q, k, v, lengths, cfg, decode_blocks=m),
            q, k, v)
        emit(f"mra_decode_s4096_m{m}", us, f"{err:.4f}")
    us = time_call(lambda q, k, v: full_decode_attention(q, k, v, lengths), q, k, v)
    emit("full_decode_s4096", us, "0.0000")
