"""Beyond-paper: MRA decode (top-k KV-block selection) quality + cost.

Per decoded token, MRA decode reads O(S/b + m*b) of the KV cache instead of
O(S). This benchmark sweeps the exact-block budget m and reports the
attention-output error vs exact decode, plus host wall-time.

Mesh-aware: under an active mesh (``benchmarks/run.py --mesh DxM``, or this
module's own ``--mesh`` flag when run standalone) the query/cache tensors
are placed batch-over-data / kv-heads-over-model and the attention runs
through the shard_map TP decode path (distributed/shard_attn.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.attention import AttentionSpec, decode_attention
from repro.distributed import mesh_utils
from repro.distributed.shard_attn import attention_partition

from .common import structured_qkv, time_call


def run(emit):
    rng = np.random.default_rng(3)
    B, Hq, Hkv, S, D, b = 4, 8, 2, 4096, 64, 32
    _, k, v = structured_qkv(rng, B=B, H=Hkv, N=S, D=D)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)

    mesh = mesh_utils.get_mesh()
    shard = mesh is not None
    if shard:
        # place operands with the exact partition the shard_map in_specs will
        # use (distributed/shard_attn.py) — any other rule means a reshard on
        # entry and the benchmark would time data movement, not attention.
        parts = attention_partition(mesh, B, Hkv)
        if parts is not None:
            bpart, hpart = parts
            s4 = NamedSharding(mesh, P(bpart, hpart, None, None))
            q = jax.device_put(q, s4)
            k = jax.device_put(k, s4)
            v = jax.device_put(v, s4)
            lengths = jax.device_put(lengths, NamedSharding(mesh, P(bpart)))

    full_spec = AttentionSpec(kind="full", shard=shard)
    ref = decode_attention(q, k, v, lengths, full_spec)
    for m in (4, 16, 64):
        spec = AttentionSpec(kind="mra2", block_size=b, decode_blocks=m,
                             shard=shard)
        out = decode_attention(q, k, v, lengths, spec)
        err = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        us = time_call(
            lambda q, k, v: decode_attention(q, k, v, lengths, spec), q, k, v)
        emit(f"mra_decode_s4096_m{m}", us, f"{err:.4f}")
    us = time_call(
        lambda q, k, v: decode_attention(q, k, v, lengths, full_spec), q, k, v)
    emit("full_decode_s4096", us, "0.0000")

    # ring-paged decode (DESIGN.md §9): a 6144-token stream served through the
    # 4096-token ring — live window is blocks nb/2 .. 3nb/2-1, with the newer
    # half wrapped onto pages 0..nb/2-1 (ring layout). Conformance: must match
    # the same window laid out contiguously (rebased); derived = that error.
    nb = S // b
    spec = AttentionSpec(kind="mra2", block_size=b, decode_blocks=16,
                         shard=shard)
    lengths2 = jnp.full((B,), S + S // 2, jnp.int32)
    blocks_contig = jnp.arange(nb, dtype=jnp.int32) + nb // 2  # ascending
    pb_contig = jnp.broadcast_to(blocks_contig[None], (B, nb))
    # ring placement: block y lives at page y % nb -> roll the contiguous
    # layout by half a ring
    pb_ring = jnp.roll(pb_contig, nb // 2, axis=1)
    k_ring = jnp.roll(k, (nb // 2) * b, axis=2)
    v_ring = jnp.roll(v, (nb // 2) * b, axis=2)
    if shard:
        parts = attention_partition(mesh, B, Hkv)
        if parts is not None:
            bpart = parts[0]
            pb_contig = jax.device_put(pb_contig, NamedSharding(mesh, P(bpart, None)))
            pb_ring = jax.device_put(pb_ring, NamedSharding(mesh, P(bpart, None)))
            k_ring = jax.device_put(k_ring, s4)
            v_ring = jax.device_put(v_ring, s4)
    ref2 = decode_attention(q, k, v, lengths2, spec, page_blocks=pb_contig)
    out2 = decode_attention(q, k_ring, v_ring, lengths2, spec,
                            page_blocks=pb_ring)
    err = float(jnp.abs(out2 - ref2).max())
    us = time_call(
        lambda q, k_ring, v_ring: decode_attention(
            q, k_ring, v_ring, lengths2, spec, page_blocks=pb_ring),
        q, k_ring, v_ring)
    emit("mra_decode_paged_ring_s4096", us, f"{err:.6f}")

    # fused Pallas serving kernel rows (DESIGN.md §11): same selection, the
    # gather + two-level softmax + background + normalize fused on-chip.
    # Interpret mode off-TPU, so the absolute time only proves the path runs
    # end-to-end; the kernel-vs-jnp ratio is meaningful on real TPUs. The
    # derived column doubles as the online parity check vs the jnp rows.
    interpret = jax.devices()[0].platform != "tpu"
    kspec = AttentionSpec(kind="mra2", block_size=b, decode_blocks=16,
                          use_kernel=True, interpret=interpret, shard=shard)
    out_k = decode_attention(q, k, v, lengths, kspec)
    err = float(jnp.linalg.norm(out_k - ref) / jnp.linalg.norm(ref))
    us = time_call(
        lambda q, k, v: decode_attention(q, k, v, lengths, kspec), q, k, v)
    emit("mra_decode_s4096_m16_kernel", us, f"{err:.4f}")
    out2k = decode_attention(q, k_ring, v_ring, lengths2, kspec,
                             page_blocks=pb_ring)
    err = float(jnp.abs(out2k - ref2).max())
    us = time_call(
        lambda q, k_ring, v_ring: decode_attention(
            q, k_ring, v_ring, lengths2, kspec, page_blocks=pb_ring),
        q, k_ring, v_ring)
    emit("mra_decode_paged_ring_s4096_kernel", us, f"{err:.6f}")


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1",
                    help="device mesh 'D' or 'DxM' (default: 1 = no mesh)")
    args = ap.parse_args()

    from repro.launch.mesh import parse_mesh

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    with mesh_utils.use_mesh(parse_mesh(args.mesh)):
        run(emit)


if __name__ == "__main__":
    main()
